"""Crash-safe warm restart: durable serving state, journaled recovery.

The PR 9 tentpole's other half.  With ``durable_dir`` set, the engine
writes every externally-visible transition (submit / add / admit /
block / cancel / finish / retire) to an fsync'd write-ahead journal
(:class:`repro.checkpoint.store.BlobLog`) and lands a full snapshot
every ``snapshot_every`` blocks.  ``Engine.recover(dir)`` on a FRESH
engine restores the newest snapshot and re-executes the journal tail —
deterministic replay, so every in-flight stream resumes byte-identical
to an uninterrupted run.

Pinned here:

* **BlobLog framing** — round-trip, reopen-and-continue, torn-tail
  truncation (a crash mid-append drops only the torn frame), and the
  refusal to silently skip mid-file corruption.
* **Crash conformance** — ``InjectedCrash`` (a BaseException: nothing
  in-process may swallow it) at EVERY block round of the run, across
  serving families × cache layouts × speculation, always recovering to
  the clean run's exact ``done`` list (content AND order).
* **Journal-only recovery** — a crash before the first snapshot lands
  replays the whole history from the log alone.
* **Warm prefix index** — committed preamble pages survive the
  restart: post-recovery admissions of a shared prefix HIT instead of
  re-prefilling.
* **Forward-compat** — PR 6-era snapshot dicts (no class counters,
  tuple ``head_blocked``, no prefix/journal fields) still restore.
"""

import os

import numpy as np
import pytest

from repro.checkpoint.store import BlobLog
from repro.dist.constrain import use_mesh
from repro.ft import CRASH_KIND, InjectedCrash, ServingFaultInjector
from repro.launch.lifecycle import PriorityClass, RequestStatus
from repro.launch.serve import Engine

from test_paged_serving import _prompts, _setup

PAGED = dict(paged=True, page_size=4, num_pages=16)


# ===========================================================================
class TestBlobLog:
    def test_append_read_round_trip(self, tmp_path):
        log = BlobLog(str(tmp_path / "j.log"))
        recs = [("submit", {"id": 0}), ("block", 4), ("retire",)]
        assert [log.append(r) for r in recs] == [0, 1, 2]
        assert log.count == 3
        assert log.read() == recs
        assert log.read(1) == recs[1:]
        log.close()

    def test_reopen_continues_after_existing_records(self, tmp_path):
        path = str(tmp_path / "j.log")
        log = BlobLog(path)
        log.append("a")
        log.append("b")
        log.close()
        log2 = BlobLog(path)
        assert log2.count == 2
        log2.append("c")
        assert log2.read() == ["a", "b", "c"]
        log2.close()

    def test_torn_tail_is_truncated_on_open(self, tmp_path):
        """A crash mid-append leaves a partial frame; reopening keeps
        every complete record and drops exactly the torn bytes."""
        path = str(tmp_path / "j.log")
        log = BlobLog(path)
        log.append("kept-1")
        log.append("kept-2")
        log.close()
        size = os.path.getsize(path)
        with open(path, "ab") as f:
            f.write(b"\x40\x00\x00\x00\x99")   # header + 1 of 64 bytes
        log2 = BlobLog(path)
        assert log2.count == 2
        assert log2.read() == ["kept-1", "kept-2"]
        log2.close()
        assert os.path.getsize(path) == size   # torn bytes are gone

    def test_mid_file_corruption_is_refused(self, tmp_path):
        """A broken frame FOLLOWED by valid data is damage, not a torn
        append — silently resuming past it would replay a wrong
        history, so opening raises instead."""
        path = str(tmp_path / "j.log")
        log = BlobLog(path)
        log.append("one")
        off = os.path.getsize(path)
        log.append("two" * 10)
        log.close()
        with open(path, "r+b") as f:
            f.seek(off + 8)                    # a payload byte of rec 2
            b = f.read(1)
            f.seek(off + 8)
            f.write(bytes([b[0] ^ 0xFF]))      # CRC now mismatches
        with open(path, "ab") as f:            # valid-looking data after
            f.write(b"x" * 64)
        with pytest.raises(IOError, match="corrupt"):
            BlobLog(path)

    def test_fresh_discards_previous_contents(self, tmp_path):
        path = str(tmp_path / "j.log")
        log = BlobLog(path)
        log.append("old")
        log.close()
        log2 = BlobLog(path, fresh=True)
        assert log2.count == 0
        assert log2.read() == []
        log2.close()


# ===========================================================================
def _drive(eng, prompts, *, gen_len=6, block=4, prios=None):
    for i, p in enumerate(prompts):
        eng.submit(p, gen_len=gen_len,
                   priority=None if prios is None else prios[i])
    eng.try_admit()
    while eng.live.any() or eng.waiting:
        eng.step_many(block)
    eng.retire_finished()
    return eng


def _engine(setup, **kw):
    cfg, ctx, params, mesh = setup
    kw.setdefault("batch", 2)
    kw.setdefault("max_len", 32)
    return Engine(cfg, ctx, params, mesh, **kw)


def _crash_recover(setup, prompts, directory, crash_round, *,
                   snapshot_every=2, gen_len=6, block=4, prios=None,
                   **kw):
    """Run durably, die at ``crash_round``, recover a fresh engine from
    the directory, finish the work.  Returns the recovered engine."""
    with use_mesh(setup[3]):
        eng = _engine(setup, durable_dir=str(directory),
                      snapshot_every=snapshot_every,
                      fault_injector=ServingFaultInjector(
                          {crash_round: CRASH_KIND}), **kw)
        with pytest.raises(InjectedCrash):
            _drive(eng, prompts, gen_len=gen_len, block=block,
                   prios=prios)
        # "fresh process": same construction args, NO durable_dir (that
        # would truncate the journal it is about to replay), no injector
        eng2 = _engine(setup, **kw)
        eng2.recover(str(directory))
        while eng2.live.any() or eng2.waiting:
            eng2.step_many(block)
        eng2.retire_finished()
    return eng2


# ===========================================================================
class TestCrashRecoveryConformance:
    """InjectedCrash at every round index; recovered streams must equal
    the uninterrupted run's ``done`` — content AND completion order."""

    CELLS = [
        ("lm", {}, False),
        ("lm", dict(PAGED), False),
        pytest.param("lm", dict(PAGED), True, marks=pytest.mark.slow),
        pytest.param("lm", {}, True, marks=pytest.mark.slow),
        pytest.param("ssm", {}, False, marks=pytest.mark.slow),
        pytest.param("ssm", dict(PAGED), False, marks=pytest.mark.slow),
        pytest.param("ssm", dict(PAGED), True, marks=pytest.mark.slow),
        pytest.param("hybrid", {}, False, marks=pytest.mark.slow),
        pytest.param("hybrid", dict(PAGED), False,
                     marks=pytest.mark.slow),
        pytest.param("hybrid", dict(PAGED), True,
                     marks=pytest.mark.slow),
    ]

    @pytest.mark.parametrize("family,kw,spec", CELLS)
    def test_crash_at_every_round(self, tmp_path, family, kw, spec):
        setup = _setup(family, "f32")
        # spec commits up to k+1 tokens per verify round, so the default
        # workload finishes in too few blocks to crash at — stretch the
        # generation and shrink the block to keep >=3 block boundaries
        drive = dict(gen_len=12, block=2) if spec else {}
        if spec:
            kw = dict(kw, spec=True)
        prompts = _prompts(setup[0], (9, 5, 12, 3), seed=31)
        prios = ("batch", "realtime", None, "standard")
        with use_mesh(setup[3]):
            clean = _drive(_engine(setup, **kw), prompts, prios=prios,
                           **drive)
        rounds = clean._round
        assert rounds >= 3, "workload too short to exercise recovery"
        for rnd in range(1, rounds + 1):
            rec = _crash_recover(setup, prompts, tmp_path / str(rnd),
                                 rnd, prios=prios, **drive, **kw)
            assert rec.done == clean.done, f"diverged for crash @ {rnd}"
            assert all(r["status"] is RequestStatus.COMPLETED
                       for r in rec.results.values())

    def test_journal_only_recovery_before_first_snapshot(self, tmp_path):
        """snapshot_every=0: no snapshot ever lands; recovery replays
        the ENTIRE history from the journal alone."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5, 12), seed=32)
        with use_mesh(setup[3]):
            clean = _drive(_engine(setup), prompts)
        rec = _crash_recover(setup, prompts, tmp_path, 2,
                             snapshot_every=0)
        assert rec.done == clean.done
        assert rec._journal.count > 0          # journaling resumed

    def test_recovered_engine_serves_new_requests(self, tmp_path):
        """Recovery is a restart, not a read-only post-mortem: the
        rebuilt engine keeps journaling and serves fresh traffic."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        prompts = _prompts(cfg, (9, 5), seed=33)
        rec = _crash_recover(setup, prompts, tmp_path, 2)
        before = rec._journal.count
        with use_mesh(setup[3]):
            solo = _drive(_engine(setup), _prompts(cfg, (7,), seed=34))
            _drive(rec, _prompts(cfg, (7,), seed=34))
        assert rec.done[-1] == solo.done[0]
        assert rec._journal.count > before     # journaling stayed on

    def test_recover_on_durable_engine_is_refused(self, tmp_path):
        """Constructing with durable_dir truncates the journal — the
        one wrong way to recover, refused loudly."""
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup, durable_dir=str(tmp_path))
            with pytest.raises(RuntimeError, match="durable_dir"):
                eng.recover(str(tmp_path))

    def test_crash_is_a_base_exception(self):
        """The in-process recovery loop catches RuntimeError and broad
        driver code catches Exception; a process death must sail past
        both to reach the harness."""
        assert issubclass(InjectedCrash, BaseException)
        assert not issubclass(InjectedCrash, Exception)


# ===========================================================================
class TestWarmPrefixIndex:
    def test_prefix_index_survives_restart(self, tmp_path):
        """Committed preamble pages are part of the durable state: an
        admission AFTER recovery hits the index instead of paying the
        full prefill again."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        rs = np.random.RandomState(35)
        pre = rs.randint(0, cfg.vocab, (16,))
        prompts = [np.concatenate([pre, rs.randint(0, cfg.vocab, (3,))])
                   for _ in range(3)]
        kw = dict(paged=True, page_size=4, num_pages=32,
                  prefix_cache=True, max_len=32)
        with use_mesh(setup[3]):
            clean = _drive(_engine(setup, **kw), prompts)
        rec = _crash_recover(setup, prompts, tmp_path, 3, **kw)
        assert rec.done == clean.done
        assert len(rec.prefix_index) > 0       # index came back warm
        hits = rec.counters["prefix_hits"]
        with use_mesh(setup[3]):
            _drive(rec, [np.concatenate(
                [pre, rs.randint(0, cfg.vocab, (3,))])])
        assert rec.counters["prefix_hits"] > hits, \
            "post-recovery admission missed a prefix the dead engine " \
            "had committed"


# ===========================================================================
class TestSnapshotForwardCompat:
    def _pr6_era(self, snap):
        """Strip a current snapshot down to its PR 6-era shape: single
        head-blocked tuple, no class counters, no prefix or durable
        fields, counters without the later layers' keys."""
        old = dict(snap)
        old["head_blocked"] = (None, 0)
        old.pop("class_counters", None)
        old.pop("journal_cursor", None)
        for k in ("prefix_index", "slot_shared", "pub"):
            old.pop(k, None)
        old["counters"] = {k: v for k, v in snap["counters"].items()
                           if not k.startswith(("prefix_", "cow_"))}
        for r in old["request_log"]:
            r.pop("priority", None)            # rows predate the field
        return old

    def test_legacy_snapshot_restores_with_defaults(self):
        """The snapshot comes from a PR 6-shaped engine (paged +
        preempt, NO prefix cache, no priority fields) and restores
        into a current engine with the prefix layer enabled — the
        realistic upgrade path."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (6, 5, 7), seed=36)
        kw = dict(paged=True, page_size=4, num_pages=16, max_len=32)
        with use_mesh(setup[3]):
            eng = _engine(setup, **kw)
            for p in prompts:
                eng.submit(p, gen_len=4)
            eng.try_admit()
            eng.step_many(2)
            legacy = self._pr6_era(eng.snapshot())
            eng2 = _engine(setup, prefix_cache=True, **kw)
            eng2.restore(legacy)
            # new fields defaulted: no tracked heads, zeroed class rows,
            # cold prefix index — and the engine still drains cleanly
            assert eng2._head_blocked == {}
            assert all(row == eng2._fresh_class_row()
                       for row in eng2.class_counters.values())
            assert len(eng2.prefix_index) == 0
            while eng2.live.any() or eng2.waiting:
                eng2.step_many(4)
            eng2.retire_finished()
            base = _drive(_engine(setup, **kw), prompts, gen_len=4)
        assert eng2.done == base.done
        # legacy request_log rows (no priority field) aggregate as
        # STANDARD instead of KeyError'ing
        st = eng2.stats()
        assert st["classes"]["standard"]["requests"] == len(prompts)

    def test_legacy_tuple_head_blocked_with_tracked_head(self):
        """A PR 6 tuple tracking a real head maps onto the STANDARD
        class (the only scheduling the era had) so its escalation
        count is not lost."""
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup)
            snap = eng.snapshot()
            snap["head_blocked"] = (7, 2)
            eng.restore(snap)
        assert eng._head_blocked == {PriorityClass.STANDARD: (7, 2)}
