"""Sharding rule tests: divisibility guards, param/batch/cache specs."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (batch_specs, cache_specs, guard_spec,
                                 param_specs)


@pytest.fixture(scope="module")
def mesh():
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


class TestGuardSpec:
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.integers(1, 64), min_size=1, max_size=4))
    def test_guard_never_violates_divisibility(self, dims, ):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = guard_spec(P(*(["data", "model", None, "data"][:len(dims)])),
                          dims, mesh)
        for axis, d in zip(spec, dims):
            if axis is not None:
                size = mesh.shape[axis] if isinstance(axis, str) else \
                    int(np.prod([mesh.shape[a] for a in axis]))
                assert d % size == 0

    def test_drops_nondivisible(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # with axis size 1 everything divides; simulate via tuple axis
        s = guard_spec(P(("data", "model")), (7,), mesh)
        assert s == P(None) or s == P(("data", "model"))  # 7 % 1 == 0


class TestParamSpecs:
    def test_rules_on_struct(self, mesh):
        import jax.numpy as jnp
        params = {
            "embed": {"table": jax.ShapeDtypeStruct((512, 128), jnp.float32)},
            "blocks": {"attn": {"wq": {"w": jax.ShapeDtypeStruct(
                (4, 128, 256), jnp.float32)}}},   # stacked (L, in, out)
            "norm": {"scale": jax.ShapeDtypeStruct((128,), jnp.float32)},
            "moe": {"w_gate": jax.ShapeDtypeStruct((4, 8, 128, 64),
                                                   jnp.float32)},
        }
        specs = param_specs(params, mesh)
        assert specs["embed"]["table"] == P("model", "data") or \
            specs["embed"]["table"][1] in ("data", None)
        # stacked rank: leading L axis unsharded
        wq = specs["blocks"]["attn"]["wq"]["w"]
        assert wq[0] is None
        assert specs["norm"]["scale"] == P()

    def test_all_archs_specs_cover_tree(self, mesh):
        """Every leaf of every arch gets a valid spec (no crashes, correct
        rank, divisibility respected)."""
        from repro.configs import get_config, list_archs
        from repro.models.api import get_family
        for arch in [a for a in list_archs() if a != "jet-mlp"]:
            cfg = get_config(arch).smoke()
            fam = get_family(cfg)
            shapes = jax.eval_shape(
                lambda: fam.init(jax.random.PRNGKey(0), cfg))
            specs = param_specs(shapes, mesh)
            leaves_s = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            leaves_p = jax.tree_util.tree_leaves(shapes)
            assert len(leaves_s) == len(leaves_p)
            for spec, leaf in zip(leaves_s, leaves_p):
                assert len(spec) <= len(leaf.shape)


class TestBatchCacheSpecs:
    def test_batch_leading_dp(self, mesh):
        import jax.numpy as jnp
        b = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        s = batch_specs(b, mesh)
        assert s["tokens"][0] in ("data", ("data",), None) or \
            s["tokens"][0] == ("pod", "data")

    def test_cache_specs_all_archs(self, mesh):
        from repro.configs import get_config
        from repro.models.api import get_family
        for arch in ["yi-6b", "deepseek-v2-236b", "mamba2-370m",
                     "zamba2-1.2b", "whisper-base"]:
            cfg = get_config(arch).smoke()
            fam = get_family(cfg)
            cache = jax.eval_shape(lambda: fam.init_cache(cfg, 4, 32))
            specs = cache_specs(cache, mesh)
            n = len(jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P)))
            assert n == len(jax.tree_util.tree_leaves(cache))
