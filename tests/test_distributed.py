"""Multi-device distribution tests (8 host devices via subprocess — the
test process itself must keep a single device; see conftest)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_runs_and_converges():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.dist.sharding import param_specs, batch_specs, named
        from repro.dist.constrain import use_mesh
        from repro.nn.context import QuantContext
        from repro.train.step import build_train_step, init_state
        from repro.data.pipeline import make_batch

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("yi-6b").smoke()
        ctx = QuantContext(compute_dtype=jnp.float32)
        step = build_train_step(cfg, ctx, lr_fn=lambda s: 3e-3,
                                microbatches=2)
        with use_mesh(mesh):
            state = init_state(jax.random.PRNGKey(0), cfg)
            st_sh = named(param_specs(state, mesh), mesh)
            state = jax.device_put(state, st_sh)
            b = make_batch(cfg, 0, 8, 32)
            b_sh = named(batch_specs(b, mesh), mesh)
            rep = NamedSharding(mesh, P())
            jstep = jax.jit(step, in_shardings=(st_sh, b_sh),
                            out_shardings=(st_sh, rep),
                            donate_argnums=(0,))
            losses = []
            for i in range(24):
                batch = jax.device_put(make_batch(cfg, i, 8, 32), b_sh)
                state, m = jstep(state, batch)
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.3, losses
        print("CONVERGED", losses[0], "->", losses[-1])
    """)
    assert "CONVERGED" in out


@pytest.mark.slow
def test_quantized_psum_matches_exact():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.qtypes import FixedPointType
        from repro.dist.compression import quantized_psum, shard_map

        mesh = jax.make_mesh((8,), ("pod",))
        x = jnp.asarray(np.random.RandomState(0).randn(8, 64),
                        jnp.float32)

        def f(x):
            exact = jax.lax.psum(x, "pod")
            q = quantized_psum(x, "pod", FixedPointType(8, 1))
            return exact, q

        exact, q = shard_map(
            f, mesh=mesh, in_specs=jax.sharding.PartitionSpec("pod"),
            out_specs=jax.sharding.PartitionSpec("pod"))(x)
        rel = float(jnp.abs(exact - q).max() /
                    (jnp.abs(exact).max() + 1e-9))
        assert rel < 0.05, rel           # int8 payload: ~1% error
        print("COMPRESSION OK", rel)
    """)
    assert "COMPRESSION OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_across_meshes():
    """Save sharded on a (4,2) mesh, restore onto (2,4) and (8,1)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.dist.sharding import param_specs, named
        from repro.models.api import get_family

        cfg = get_config("gemma-2b").smoke()
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        d = tempfile.mkdtemp()
        m1 = jax.make_mesh((4, 2), ("data", "model"))
        p1 = jax.device_put(params, named(param_specs(params, m1), m1))
        mgr = CheckpointManager(d)
        mgr.save({"params": p1}, 1, blocking=True)

        for shape in [(2, 4), (8, 1)]:
            m2 = jax.make_mesh(shape, ("data", "model"))
            sh2 = named(param_specs({"params": params}, m2), m2)
            restored, step = mgr.restore_latest({"params": params},
                                                shardings=sh2)
            assert step == 1
            for a, b in zip(jax.tree_util.tree_leaves(restored),
                            jax.tree_util.tree_leaves(params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("ELASTIC OK")
    """)
    assert "ELASTIC OK" in out


@pytest.mark.slow
def test_pod_sharded_grad_compression_lowers():
    """shard_map(manual over pod, auto inside) + quantized psum compiles
    on a (2,2,2) pod mesh."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.qtypes import FixedPointType
        from repro.dist.compression import make_pod_sharded_grad_fn

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

        def grad_fn(params, batch):
            def loss(p):
                return jnp.mean((batch @ p) ** 2)
            return jax.grad(loss)(params), {"loss": jnp.zeros(())}

        f = make_pod_sharded_grad_fn(
            grad_fn, mesh,
            in_specs=(P(), P("pod")),
            out_specs=(P(), P()),
            qtype=FixedPointType(8, 1))
        params = jnp.asarray(np.random.RandomState(0).randn(16, 4),
                             jnp.float32)
        batch = jnp.asarray(np.random.RandomState(1).randn(8, 16),
                            jnp.float32)
        with mesh:
            g, m = jax.jit(f)(params, batch)
        assert g.shape == params.shape
        print("POD COMPRESS OK", float(jnp.abs(g).max()))
    """)
    assert "POD COMPRESS OK" in out
