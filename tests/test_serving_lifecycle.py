"""Request-lifecycle robustness: validation, status, cancel, deadlines.

Every request now moves through an explicit state machine (QUEUED →
RUNNING → {COMPLETED, CANCELLED, TIMED_OUT, PREEMPTED, FAILED}) keyed
by the id ``submit`` returns.  This suite pins the host-side contract:

* malformed requests are rejected at ``submit()`` — one test per
  rejection class — before they can poison a device batch;
* ``status``/``cancel``/``results`` behave at every lifecycle stage,
  and a cancelled lane recycles cleanly (the next request's stream is
  byte-identical to a fresh engine's);
* deadlines are TTLs checked at block boundaries against the engine's
  injectable clock — expired requests finish TIMED_OUT with their
  partial output instead of raising;
* the wired-in StragglerMonitor flags slow blocks in ``stats()``;
* pressure shedding changes block shape, never greedy streams.
"""

import numpy as np
import pytest

from repro.dist.constrain import use_mesh
from repro.ft import ServingFaultInjector, StragglerMonitor
from repro.launch.lifecycle import (PriorityClass, RequestStatus,
                                    validate_request)
from repro.launch.serve import Engine

from test_paged_serving import _prompts, _serve, _setup


class FakeClock:
    """Deterministic time source for the engine's ``clock`` seam."""

    def __init__(self, t=0.0, tick=0.0):
        self.t = float(t)
        self.tick = float(tick)     # auto-advance per read (block timing)

    def __call__(self):
        self.t += self.tick
        return self.t

    def advance(self, dt):
        self.t += float(dt)


def _engine(setup, **kw):
    cfg, ctx, params, mesh = setup
    kw.setdefault("batch", 2)
    kw.setdefault("max_len", 24)
    return Engine(cfg, ctx, params, mesh, **kw)


def _drain(eng, block=4):
    while eng.live.any() or eng.waiting:
        eng.step_many(block)
    eng.retire_finished()
    return eng


# ===========================================================================
class TestInputValidation:
    """One rejection test per malformed-request class: each must raise
    at submit() and leave the queue untouched."""

    def _eng(self):
        return _engine(_setup("lm", "f32"))

    def test_rejects_negative_temperature(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = self._eng()
            with pytest.raises(ValueError, match="temperature"):
                eng.submit(_prompts(setup[0], (4,))[0], temperature=-0.5)
            assert not eng.waiting

    def test_rejects_negative_top_k(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = self._eng()
            with pytest.raises(ValueError, match="top_k"):
                eng.submit(_prompts(setup[0], (4,))[0], top_k=-3)
            assert not eng.waiting

    def test_rejects_non_integer_token_ids(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = self._eng()
            with pytest.raises(ValueError, match="integer"):
                eng.submit(np.array([1.0, 2.5, 3.0]))
            assert not eng.waiting

    def test_rejects_out_of_vocab_token_ids(self):
        setup = _setup("lm", "f32")
        cfg = setup[0]
        with use_mesh(setup[3]):
            eng = self._eng()
            with pytest.raises(ValueError, match="vocab"):
                eng.submit(np.array([0, cfg.vocab], np.int32))
            with pytest.raises(ValueError, match="vocab"):
                eng.submit(np.array([-1, 0], np.int32))
            assert not eng.waiting

    def test_rejects_non_positive_deadline(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = self._eng()
            with pytest.raises(ValueError, match="deadline"):
                eng.submit(_prompts(setup[0], (4,))[0], deadline_s=0.0)
            assert not eng.waiting

    def test_rejects_unknown_priority_class(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = self._eng()
            with pytest.raises(ValueError, match="priority"):
                eng.submit(_prompts(setup[0], (4,))[0], priority="urgent")
            with pytest.raises(ValueError, match="out of range"):
                eng.submit(_prompts(setup[0], (4,))[0], priority=-1)
            assert not eng.waiting

    def test_rejects_bad_slo_targets_at_construction(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        with use_mesh(mesh):
            with pytest.raises(ValueError, match="positive"):
                _engine(setup, slo_targets={"realtime": {"ttft_s": 0.0}})
            with pytest.raises(ValueError, match="unknown SLO target"):
                _engine(setup, slo_targets={"realtime": {"latency": 1.0}})
            with pytest.raises(ValueError, match="priority"):
                _engine(setup, slo_targets={"urgent": {"ttft_s": 1.0}})

    def test_validate_request_accepts_and_canonicalizes(self):
        out = validate_request([3, 1, 4], vocab=10, temperature=0.7,
                               top_k=5, deadline_s=1.0)
        assert out.dtype == np.int32 and out.tolist() == [3, 1, 4]
        # per-slot dicts (the add_requests path) validate per entry
        with pytest.raises(ValueError, match="temperature"):
            validate_request([1], vocab=10, temperature={0: 0.5, 1: -1.0})

    def test_direct_add_requests_validates_too(self):
        """Slot-addressed admission goes through the same gate."""
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = self._eng()
            with pytest.raises(ValueError, match="vocab"):
                eng.add_requests({0: np.array([setup[0].vocab], np.int32)},
                                 gen_len=2)
            assert not eng.live.any()


# ===========================================================================
class TestStatusAndResults:
    def test_lifecycle_queued_running_completed(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (6, 6, 6))
        with use_mesh(mesh):
            eng = _engine(setup)
            ids = [eng.submit(p, gen_len=4) for p in prompts]
            assert ids == [0, 1, 2]                  # minted in order
            assert all(eng.status(i) is RequestStatus.QUEUED for i in ids)
            eng.try_admit()
            # two lanes: first two run, third still queued
            assert eng.status(ids[0]) is RequestStatus.RUNNING
            assert eng.status(ids[2]) is RequestStatus.QUEUED
            _drain(eng)
        for i in ids:
            assert eng.status(i) is RequestStatus.COMPLETED
            assert eng.results[i]["status"] is RequestStatus.COMPLETED
        # results carry exactly the per-request streams `done` has
        assert [eng.results[i]["tokens"] for i in ids] == eng.done
        assert eng.status(999) is None               # unknown id

    def test_stats_surfaces_lifecycle_counters(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup)
            eng.submit(_prompts(setup[0], (4,))[0], gen_len=2)
            _drain(eng, block=2)
        st = eng.stats()
        for key in ("queued", "preemptions", "cancellations", "timeouts",
                    "failures", "replays", "spilled_pages",
                    "shed_spec_rounds", "straggler_blocks",
                    "straggler_events"):
            assert key in st
        assert st["queued"] == 0


# ===========================================================================
class TestCancel:
    def test_cancel_queued_request(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup)
            rid = eng.submit(_prompts(setup[0], (4,))[0], gen_len=4)
            assert eng.cancel(rid)
            assert not eng.waiting
        assert eng.status(rid) is RequestStatus.CANCELLED
        assert eng.results[rid]["tokens"] == []
        assert eng.counters["cancellations"] == 1
        assert not eng.cancel(rid)                   # already terminal

    def test_cancel_running_keeps_partial_output_and_recycles_lane(self):
        """A mid-stream cancel finishes the lane NOW with the partial
        tokens; the recycled lane must serve the next request exactly
        as a fresh engine would (no stale-state leak)."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (8, 8), seed=4)
        with use_mesh(mesh):
            eng = _engine(setup, batch=1)
            rid0 = eng.submit(prompts[0], gen_len=12)
            rid1 = eng.submit(prompts[1], gen_len=6)
            eng.try_admit()
            eng.step_many(3)                         # partial progress
            assert eng.cancel(rid0)
            assert eng.status(rid0) is RequestStatus.CANCELLED
            assert len(eng.results[rid0]["tokens"]) == 3
            _drain(eng)

            solo = _engine(setup, batch=1)
            solo.submit(prompts[1], gen_len=6)
            _drain(solo)
        assert eng.results[rid1]["tokens"] == solo.done[0]
        assert eng.status(rid1) is RequestStatus.COMPLETED

    def test_cancel_running_paged_frees_pages(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup, paged=True, page_size=4, num_pages=12)
            rid = eng.submit(_prompts(setup[0], (8,))[0], gen_len=8)
            eng.try_admit()
            assert eng.allocator.used_pages > 0
            eng.step_many(2)
            assert eng.cancel(rid)
            assert eng.allocator.used_pages == 0

    def test_cancel_unknown_id(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup)
            assert not eng.cancel(123)


# ===========================================================================
class TestDeadlines:
    def test_queued_request_times_out_without_a_lane(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        clock = FakeClock()
        prompts = _prompts(cfg, (6, 6), seed=5)
        with use_mesh(mesh):
            eng = _engine(setup, batch=1, clock=clock)
            rid0 = eng.submit(prompts[0], gen_len=8)
            rid1 = eng.submit(prompts[1], gen_len=4, deadline_s=5.0)
            eng.try_admit()                          # rid0 takes the lane
            clock.advance(10.0)                      # rid1's TTL expires
            eng.step_many(2)
        assert eng.status(rid1) is RequestStatus.TIMED_OUT
        assert eng.results[rid1]["tokens"] == []
        assert eng.counters["timeouts"] == 1
        assert eng.status(rid0) is RequestStatus.RUNNING  # unaffected

    def test_running_request_times_out_with_partial_output(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        clock = FakeClock()
        with use_mesh(mesh):
            eng = _engine(setup, clock=clock)
            rid = eng.submit(_prompts(cfg, (6,))[0], gen_len=16,
                             deadline_s=5.0)
            eng.try_admit()
            eng.step_many(3)                         # 3 tokens committed
            clock.advance(10.0)
            eng.step_many(1)                         # boundary sweep fires
        assert eng.status(rid) is RequestStatus.TIMED_OUT
        # partial output is returned, not discarded: the 3 pre-expiry
        # tokens (the sweep runs before the block decodes more)
        assert len(eng.results[rid]["tokens"]) == 3
        assert eng.counters["timeouts"] == 1

    def test_dict_deadlines_apply_per_request(self):
        """``deadline_s={slot: ttl}`` with mixed None entries: only the
        tight-TTL request times out; the no-deadline one runs to
        completion.  Failing-before: validation collapsed the dict
        with ``min(values())`` — a TypeError the moment one entry was
        None, and the whole batch judged by the tightest TTL."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        clock = FakeClock()
        prompts = _prompts(cfg, (6, 6), seed=7)
        with use_mesh(setup[3]):
            eng = _engine(setup, batch=2, clock=clock)
            eng.add_requests({0: prompts[0], 1: prompts[1]}, gen_len=8,
                             deadline_s={0: 5.0, 1: None})
            eng.step_many(2)                     # both decode 2 tokens
            clock.advance(10.0)                  # slot 0's TTL expires
            _drain(eng, block=2)
        assert eng.counters["timeouts"] == 1
        # tight slot returns its partial output; open slot is untouched
        by_status = {r["status"]: r["tokens"] for r in
                     eng.results.values()}
        assert 0 < len(by_status[RequestStatus.TIMED_OUT]) < 8
        assert len(by_status[RequestStatus.COMPLETED]) == 8

    def test_mixed_none_dict_deadline_validates(self):
        """Regression: the collapsed-min validation crashed on mixed
        None before a single request was admitted."""
        validate_request([], vocab=64, deadline_s={0: 1.0, 1: None})
        with pytest.raises(ValueError, match="deadline"):
            validate_request([], vocab=64, deadline_s={0: 1.0, 1: -2.0})

    def test_no_deadline_means_no_timeout(self):
        setup = _setup("lm", "f32")
        clock = FakeClock()
        with use_mesh(setup[3]):
            eng = _engine(setup, clock=clock)
            rid = eng.submit(_prompts(setup[0], (6,))[0], gen_len=4)
            clock.advance(1e6)
            _drain(eng, block=2)
        assert eng.status(rid) is RequestStatus.COMPLETED

    def test_finished_unretired_slot_is_not_timed_out(self):
        """A slot whose generation already ended but whose lane has not
        retired yet must finish COMPLETED even if its TTL has passed —
        the deadline governs decoding, not retirement latency."""
        setup = _setup("lm", "f32")
        clock = FakeClock()
        with use_mesh(setup[3]):
            eng = _engine(setup, clock=clock)
            rid = eng.submit(_prompts(setup[0], (6,))[0], gen_len=2,
                             deadline_s=50.0)
            eng.try_admit()
            eng.step_many(4)              # generation ends inside block
            assert not eng.live.any()
            clock.advance(100.0)
            eng.step_many(1)              # sweep sees a dead, done slot
            eng.retire_finished()
        assert eng.status(rid) is RequestStatus.COMPLETED


# ===========================================================================
class TestStraggler:
    def test_injected_slow_block_is_flagged(self):
        """The slow fault adds a deterministic synthetic penalty through
        the clock seam; after a warmup history the monitor flags it and
        the event lands in stats()."""
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(
                setup,
                fault_injector=ServingFaultInjector({8: "slow"}),
                straggler=StragglerMonitor(window=8, ratio=1.5, patience=1))
            eng.submit(_prompts(setup[0], (4,))[0], gen_len=12)
            eng.try_admit()
            for _ in range(12):
                if not (eng.live.any() or eng.waiting):
                    break
                eng.step_many(1)
        st = eng.stats()
        assert eng.fault_injector.events == [(8, "slow")]
        assert st["straggler_blocks"] >= 1
        assert st["straggler_events"]
        # flagged round recorded with its (inflated) duration
        rounds = [r for r, _ in eng.straggler.events]
        assert 8 in rounds

    def test_clean_run_flags_nothing(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(
                setup,
                straggler=StragglerMonitor(window=8, ratio=100.0,
                                           patience=1))
            eng.submit(_prompts(setup[0], (4,))[0], gen_len=10)
            _drain(eng, block=1)
        assert eng.stats()["straggler_blocks"] == 0
        assert not eng.straggler.events


# ===========================================================================
class TestShedding:
    def test_shed_blocks_keep_streams_identical(self):
        """Past the occupancy threshold the engine halves its block —
        a shape change only: greedy streams must not move."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (8, 6, 9), seed=6)
        base = _serve(setup, prompts, gen_len=6, max_len=24,
                      paged=True, page_size=4, num_pages=12)
        shed = _serve(setup, prompts, gen_len=6, max_len=24,
                      paged=True, page_size=4, num_pages=12,
                      shed_threshold=0.25)
        assert shed.done == base.done

    def test_shed_drops_speculation_under_pressure(self):
        """With speculation on and the pool past threshold, spec rounds
        are shed (counted) and the stream still matches the plain dense
        engine byte for byte."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (8, 8), seed=7)
        dense = _serve(setup, prompts, gen_len=6, max_len=24)
        shed = _serve(setup, prompts, gen_len=6, max_len=24,
                      paged=True, page_size=4, num_pages=8,
                      spec=True, shed_threshold=0.1)
        assert shed.done == dense.done
        assert shed.counters["shed_spec_rounds"] > 0


# ===========================================================================
class TestEscalationCounter:
    """The ``_head_blocked`` escalation counter tracks ONE head per
    priority class across admission sweeps.  Regression: popping any
    *other* record (a resume, a small admission slipping into a free
    lane) used to reset the counter, so interleaved progress kept a
    blocked head exactly one sweep short of preempting, forever."""

    def test_interleaved_pop_does_not_reset_blocked_head(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (10, 12, 3), seed=8)
        with use_mesh(mesh):
            # pool of 8: A (10+6 -> 4 pages) fits; B (12+8 -> 5 pages)
            # blocks behind it; C (3+2 -> 2 pages) fits beside A
            eng = _engine(setup, clock=FakeClock(), paged=True,
                          page_size=4, num_pages=8, max_len=24,
                          preempt=True, preempt_after=3)
            rid_a = eng.submit(prompts[0], gen_len=6)
            eng.try_admit()
            assert eng.status(rid_a) is RequestStatus.RUNNING
            rid_b = eng.submit(prompts[1], gen_len=8)
            std = PriorityClass.STANDARD         # default class
            eng.try_admit()                      # blocked sweep 1
            assert eng._head_blocked == {std: (rid_b, 1)}
            # a small request cuts the line (models a resume record,
            # which re-enters at the queue head) and takes the free
            # lane — its pop must NOT clobber B's escalation count
            rid_c = eng.submit(prompts[2], gen_len=2)
            eng.waiting.appendleft(eng.waiting.pop())
            eng.try_admit()
            assert eng.status(rid_c) is RequestStatus.RUNNING
            assert eng._head_blocked == {std: (rid_b, 1)}   # preserved
            assert eng.cancel(rid_c)             # lane/pages free again
            eng.try_admit()                      # blocked sweep 2
            assert eng._head_blocked == {std: (rid_b, 2)}
            assert eng.counters["preemptions"] == 0
            eng.try_admit()                      # sweep 3 == preempt_after
            # escalation fires exactly on schedule: A spills, B runs
            assert eng.counters["preemptions"] == 1
            assert eng.status(rid_a) is RequestStatus.PREEMPTED
            assert eng.status(rid_b) is RequestStatus.RUNNING
            # B's pop reset the counter; A's spilled resume record is
            # the new queue head and starts its OWN count from 1
            assert eng._head_blocked == {std: (rid_a, 1)}
            _drain(eng)                          # B finishes, A resumes
            assert eng.status(rid_a) is RequestStatus.COMPLETED
            assert eng.status(rid_b) is RequestStatus.COMPLETED


# ===========================================================================
class TestThroughputRows:
    """``tok_per_s`` is ``None`` — not 0.0 — when the decode interval
    is unmeasurable; aggregates skip those rows instead of dragging
    the mean toward a fictitious zero."""

    def test_zero_interval_rows_are_none_and_skip_the_mean(self):
        setup = _setup("lm", "f32")
        clock = FakeClock()                      # frozen: dt == 0.0
        with use_mesh(setup[3]):
            eng = _engine(setup, clock=clock)
            eng.submit(_prompts(setup[0], (4,))[0], gen_len=3)
            _drain(eng, block=3)
            clock.tick = 0.05                    # time now passes
            eng.submit(_prompts(setup[0], (5,))[0], gen_len=3)
            _drain(eng, block=3)
        frozen, ticking = eng.request_log
        assert frozen["decode_s"] == 0.0 and frozen["tok_per_s"] is None
        assert ticking["tok_per_s"] > 0
        # the mean covers ONLY the measurable row
        st = eng.stats()
        assert st["req_tok_per_s_mean"] == pytest.approx(
            ticking["tok_per_s"])

    def test_all_rows_unmeasurable_yields_zero_mean_not_crash(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup, clock=FakeClock())
            eng.submit(_prompts(setup[0], (4,))[0], gen_len=2)
            _drain(eng, block=2)
        assert eng.request_log[0]["tok_per_s"] is None
        assert eng.stats()["req_tok_per_s_mean"] == 0.0

    def test_engine_decode_tok_per_s_none_without_interval(self, capsys):
        """Regression: a frozen clock (decode_s == 0) made ``stats()``
        report a fictitious ``decode_tok_per_s`` of 0.0 — tokens WERE
        generated, the interval just wasn't measurable.  None is the
        honest value, and the exit table prints "n/a" for it."""
        from repro.launch.serve import print_stats_table

        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup, clock=FakeClock())
            eng.submit(_prompts(setup[0], (4,))[0], gen_len=3)
            _drain(eng, block=3)
            st = eng.stats()
        assert st["gen_tokens"] > 0
        assert st["decode_tok_per_s"] is None
        print_stats_table(st)
        assert "n/a" in capsys.readouterr().out

    def test_engine_decode_tok_per_s_measured_with_ticking_clock(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup, clock=FakeClock(tick=0.01))
            eng.submit(_prompts(setup[0], (4,))[0], gen_len=3)
            _drain(eng, block=3)
            st = eng.stats()
        assert st["decode_tok_per_s"] is not None
        assert st["decode_tok_per_s"] > 0
