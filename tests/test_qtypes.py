"""Property + unit tests for the parametric numeric formats (paper §IV)."""

import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.qtypes import (AC_FIXED_16_6, AC_FIXED_18_8, E4M3, E5M2,
                               FixedPointType, MiniFloatType, storage_dtype)

fixed_types = st.builds(
    FixedPointType,
    width=st.integers(2, 18),
    int_bits=st.integers(1, 10),
    signed=st.just(True),
    rounding=st.sampled_from(["rnd_even", "rnd", "trn"]),
    overflow=st.sampled_from(["sat", "wrap"]),
).filter(lambda t: t.int_bits <= t.width)


class TestFixedPoint:
    def test_classic_hls4ml_types(self):
        # ac_fixed<16,6>: lsb 2^-10, range [-32, 32)
        assert AC_FIXED_16_6.lsb == 2.0 ** -10
        assert AC_FIXED_16_6.min_value == -32.0
        assert AC_FIXED_16_6.max_value == 32.0 - 2.0 ** -10
        # the paper's softmax table type, sized for an 18k BRAM
        assert AC_FIXED_18_8.width == 18

    def test_storage_dtype(self):
        assert storage_dtype(8) == jnp.int8
        assert storage_dtype(9) == jnp.int16
        assert storage_dtype(18) == jnp.int32
        with pytest.raises(ValueError):
            storage_dtype(40)

    @settings(max_examples=50, deadline=None)
    @given(fixed_types, st.lists(st.floats(-1000, 1000, allow_nan=False),
                                 min_size=1, max_size=16))
    def test_quantize_properties(self, t, xs):
        x = jnp.asarray(np.asarray(xs, np.float32))
        q = np.asarray(t.quantize(x))
        # closure: quantization is idempotent
        q2 = np.asarray(t.quantize(jnp.asarray(q)))
        assert np.array_equal(q, q2)
        # representable: q is an exact multiple of the lsb
        assert np.allclose(np.round(q / t.lsb), q / t.lsb, atol=1e-3)
        if t.overflow == "sat":
            assert q.min() >= t.min_value - 1e-9
            assert q.max() <= t.max_value + 1e-9
            # quantization error bounded inside the range: half an lsb
            # for round modes, a full lsb for truncation
            bound = t.lsb * (1.0 if t.rounding == "trn" else 0.5) + 1e-6
            inside = (np.asarray(xs) >= t.min_value) & \
                     (np.asarray(xs) <= t.max_value)
            assert np.all(np.abs(q[inside] - np.asarray(xs)[inside])
                          <= bound)

    @settings(max_examples=30, deadline=None)
    @given(fixed_types)
    def test_numpy_twin_matches_jax(self, t):
        x = np.linspace(t.min_value * 1.5, t.max_value * 1.5, 257,
                        dtype=np.float32)
        a = np.asarray(t.quantize(jnp.asarray(x)))
        b = t.np_quantize(x)
        assert np.allclose(a, b, atol=t.lsb * 0.51), (t,)

    def test_monotone_sat(self):
        t = FixedPointType(8, 3)
        x = jnp.linspace(-10, 10, 1001)
        q = np.asarray(t.quantize(x))
        assert np.all(np.diff(q) >= -1e-9)


class TestMiniFloat:
    def test_e4m3_matches_ml_dtypes(self):
        rng = np.random.RandomState(0)
        xs = np.concatenate([
            rng.randn(5000).astype(np.float32) * 100,
            np.asarray([0.0, -0.0, 448.0, 464.0, 1e-9, 2**-9, -2**-10],
                       np.float32)])
        ours = np.asarray(E4M3.quantize(jnp.asarray(xs)))
        ref = np.clip(xs, -448, 448).astype(ml_dtypes.float8_e4m3fn
                                            ).astype(np.float32)
        assert np.array_equal(ours, ref)

    def test_e5m2_matches_ml_dtypes(self):
        rng = np.random.RandomState(1)
        xs = rng.randn(5000).astype(np.float32) * 3000
        ours = np.asarray(E5M2.quantize(jnp.asarray(xs)))
        ref = np.clip(xs, -57344, 57344).astype(ml_dtypes.float8_e5m2
                                                ).astype(np.float32)
        assert np.array_equal(ours, ref)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(2, 6), st.integers(1, 5),
           st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1,
                    max_size=16))
    def test_minifloat_properties(self, e, m, xs):
        t = MiniFloatType(e, m)
        x = jnp.asarray(np.asarray(xs, np.float32))
        q = np.asarray(t.quantize(x))
        # idempotent
        assert np.array_equal(np.asarray(t.quantize(jnp.asarray(q))), q)
        # bounded by max finite
        assert np.all(np.abs(q) <= t.max_value + 1e-9)
        # relative error bounded for in-range normal values
        xa = np.abs(np.asarray(xs, np.float32))
        normal = (xa >= 2.0 ** t.min_normal_exp) & (xa <= t.max_value)
        rel = np.abs(q - np.asarray(xs, np.float32))[normal] / xa[normal]
        assert np.all(rel <= 2.0 ** (-t.man_bits - 1) + 1e-7)

    def test_bf16_is_a_minifloat(self):
        t = MiniFloatType(8, 7)
        xs = np.random.RandomState(2).randn(2000).astype(np.float32) * 50
        ours = np.asarray(t.quantize(jnp.asarray(xs)))
        ref = xs.astype(ml_dtypes.bfloat16).astype(np.float32)
        # f32 emulation arithmetic can land one ulp off exactly at
        # round-to-even ties; require exactness on >= 99.9%
        exact = np.mean(ours == ref)
        assert exact > 0.999, exact
        np.testing.assert_allclose(ours, ref, rtol=2.0 ** -8)
