"""Data pipeline determinism + optimizer behavior."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import Prefetcher, SyntheticLM, make_batch
from repro.optim import OptConfig, adamw_init, adamw_update, cosine_warmup


class TestData:
    def test_determinism(self):
        src = SyntheticLM(1000, seed=3)
        a = src.batch(17, 4, 32)
        b = src.batch(17, 4, 32)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = src.batch(18, 4, 32)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        src = SyntheticLM(100, seed=0)
        b = src.batch(0, 2, 16)
        # label[t] is the next token after tokens[t] in the same stream
        full = src.tokens(0, 2, 16)
        np.testing.assert_array_equal(b["tokens"], full[:, :-1])
        np.testing.assert_array_equal(b["labels"], full[:, 1:])

    def test_learnable_structure(self):
        """The Markov stream has < vocab-uniform entropy (a bigram model
        can beat uniform) — guarantees train demos can reduce loss."""
        src = SyntheticLM(64, seed=0, branching=2)
        toks = src.tokens(0, 64, 128)
        pairs = {}
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                pairs.setdefault(int(a), set()).add(int(b))
        avg_successors = np.mean([len(v) for v in pairs.values()])
        assert avg_successors <= 4  # far below vocab=64

    def test_family_batches(self):
        for arch, key in [("whisper-base", "enc_input"),
                          ("llama-3.2-vision-11b", "img_embed")]:
            cfg = get_config(arch).smoke()
            b = make_batch(cfg, 0, 2, 16)
            assert key in b and b[key].shape[0] == 2

    def test_prefetcher(self):
        seen = []
        p = Prefetcher(lambda s: {"x": s * 2}, start_step=5)
        for _ in range(3):
            step, item = next(p)
            seen.append((step, item["x"]))
        p.close()
        assert seen == [(5, 10), (6, 12), (7, 14)]


class TestOptim:
    def test_adamw_converges_quadratic(self):
        params = {"w": jnp.asarray(np.random.RandomState(0).randn(16),
                                   jnp.float32)}
        target = jnp.asarray(np.random.RandomState(1).randn(16), jnp.float32)
        opt = adamw_init(params)
        cfg = OptConfig(weight_decay=0.0)
        loss = lambda p: jnp.sum((p["w"] - target) ** 2)
        for step in range(200):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw_update(g, opt, params, 0.05, cfg)
        assert float(loss(params)) < 1e-3

    def test_grad_clipping(self):
        params = {"w": jnp.zeros((4,))}
        opt = adamw_init(params)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, m = adamw_update(g, opt, params, 0.1,
                               OptConfig(clip_norm=1.0))
        assert float(m["grad_norm"]) > 1e5  # reported pre-clip

    def test_weight_decay_only_on_matrices(self):
        params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
        opt = adamw_init(params)
        g = {"w": jnp.zeros((4, 4)), "scale": jnp.zeros((4,))}
        p2, _, _ = adamw_update(g, opt, params, 0.1,
                                OptConfig(weight_decay=0.5))
        assert float(jnp.abs(p2["w"] - 1.0).max()) > 1e-3   # decayed
        np.testing.assert_array_equal(np.asarray(p2["scale"]),
                                      np.ones((4,)))        # exempt

    def test_schedule(self):
        assert float(cosine_warmup(0, peak=1.0, warmup=10, total=100)) == 0.0
        assert float(cosine_warmup(10, peak=1.0, warmup=10,
                                   total=100)) == 1.0
        end = float(cosine_warmup(100, peak=1.0, warmup=10, total=100))
        assert abs(end - 0.1) < 1e-6

    def test_bf16_second_moment_option(self):
        params = {"w": jnp.ones((4,))}
        opt = adamw_init(params, OptConfig(v_dtype=jnp.bfloat16))
        assert opt["v"]["w"].dtype == jnp.bfloat16
