"""Interpret-mode paged-attention kernel tests vs the ``ref.py`` oracle.

``paged_attention_pallas`` reads K/V through a scalar-prefetched block
table (one physical page per grid step) and must match
``paged_attention_ref`` — which gathers the pages into a contiguous view
— across the cases the table indirection actually has to handle:

* ragged block tables (every batch row at a different fill level);
* a last page that is only partially filled (qpos mid-page);
* GQA group folding (Hq > Hkv share pages, never broadcast);
* a prompt ending exactly at a page boundary (the next write starts a
  fresh page — the off-by-one magnet for ``pos // page_size``);
* chunked-prefill queries (S > 1) next to single-token decode (S == 1);
* garbage in unallocated / not-yet-written rows never leaking (recycled
  pages keep their previous occupant's KV until overwritten).

The oracle itself is cross-checked against the dense attention path on
an identity block table, so the two implementations cannot share a
common indexing mistake.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention import paged_attention_pallas
from repro.kernels.ref import paged_attention_ref

TOL = dict(rtol=2e-5, atol=2e-5)


def _case(b, hq, hkv, s, d, ps, num_pages, table_width, seed=0):
    rs = np.random.RandomState(seed)
    q = jnp.asarray(rs.randn(b, hq, s, d), jnp.float32)
    kp = jnp.asarray(rs.randn(num_pages, hkv, ps, d), jnp.float32)
    vp = jnp.asarray(rs.randn(num_pages, hkv, ps, d), jnp.float32)
    # distinct physical pages per row, deliberately shuffled so logical
    # order != physical order (the whole point of the table)
    bt = np.stack([rs.permutation(num_pages)[:table_width]
                   for _ in range(b)])
    return q, kp, vp, jnp.asarray(bt, jnp.int32)


def _check(q, kp, vp, bt, qpos):
    qpos = jnp.asarray(qpos, jnp.int32)
    got = paged_attention_pallas(q, kp, vp, bt, qpos, interpret=True)
    want = paged_attention_ref(q, kp, vp, bt, qpos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# ===========================================================================
class TestOracleAgainstDense:
    """paged_attention_ref == plain masked attention on an identity table.

    Anchors the oracle: if pages are laid out contiguously (block table
    = identity), paged attention IS dense cache attention with the
    ``kvpos <= qpos`` visibility mask."""

    @pytest.mark.parametrize("s,qpos", [(1, 11), (4, 7), (3, 0)])
    def test_identity_table_matches_dense(self, s, qpos):
        b, hq, hkv, d, ps, np_ = 2, 4, 2, 8, 4, 6
        rs = np.random.RandomState(3)
        q = jnp.asarray(rs.randn(b, hq, s, d), jnp.float32)
        kp = jnp.asarray(rs.randn(np_, hkv, ps, d), jnp.float32)
        vp = jnp.asarray(rs.randn(np_, hkv, ps, d), jnp.float32)
        bt = jnp.broadcast_to(jnp.arange(np_, dtype=jnp.int32), (b, np_))
        qpos_v = jnp.full((b,), qpos, jnp.int32)
        got = paged_attention_ref(q, kp, vp, bt, qpos_v)

        # dense reference: contiguous K/V + explicit visibility mask
        k = kp.transpose(1, 0, 2, 3).reshape(hkv, np_ * ps, d)[None]
        v = vp.transpose(1, 0, 2, 3).reshape(hkv, np_ * ps, d)[None]
        k = jnp.broadcast_to(k, (b, hkv, np_ * ps, d))
        v = jnp.broadcast_to(v, (b, hkv, np_ * ps, d))
        g = hq // hkv
        qg = q.reshape(b, hkv, g, s, d)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) * (d ** -0.5)
        vis = (jnp.arange(np_ * ps)[None, None, :]
               <= (qpos_v[:, None] + jnp.arange(s)[None, :])[:, :, None])
        logits = jnp.where(vis[:, None, None], logits, -1e30)
        want = jnp.einsum("bhgqk,bhkd->bhgqd",
                          jax.nn.softmax(logits, -1), v)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want.reshape(b, hq, s, d)), **TOL)


# ===========================================================================
class TestKernelVsOracle:
    def test_ragged_block_tables(self):
        """Every batch row at a different fill level (mixed decode)."""
        q, kp, vp, bt = _case(4, 4, 2, 1, 16, ps=4, num_pages=12,
                              table_width=5)
        _check(q, kp, vp, bt, [0, 3, 9, 17])

    def test_last_page_partial_fill(self):
        """qpos mid-page: only part of the final page is visible."""
        q, kp, vp, bt = _case(2, 2, 2, 1, 8, ps=8, num_pages=6,
                              table_width=3, seed=1)
        _check(q, kp, vp, bt, [10, 13])          # rows 2 and 5 of page 1

    @pytest.mark.parametrize("hq,hkv", [(4, 1), (8, 2), (6, 6)])
    def test_gqa_group_folding(self, hq, hkv):
        """Query heads fold onto their KV group; pages fetched per Hkv."""
        q, kp, vp, bt = _case(2, hq, hkv, 1, 8, ps=4, num_pages=8,
                              table_width=4, seed=2)
        _check(q, kp, vp, bt, [6, 11])

    @pytest.mark.parametrize("ps", [4, 8])
    def test_prompt_exactly_at_page_boundary(self, ps):
        """qpos a multiple of page_size: the query's own token is the
        first row of a fresh page and every earlier page is full."""
        q, kp, vp, bt = _case(2, 4, 2, 1, 8, ps=ps, num_pages=10,
                              table_width=4, seed=3)
        _check(q, kp, vp, bt, [2 * ps, ps])

    @pytest.mark.parametrize("s", [2, 5, 8])
    def test_chunked_prefill_queries(self, s):
        """S > 1: within-chunk causality over absolute positions."""
        q, kp, vp, bt = _case(3, 4, 2, s, 8, ps=4, num_pages=16,
                              table_width=6, seed=4)
        _check(q, kp, vp, bt, [0, 5, 9])

    def test_chunk_ending_at_page_boundary(self):
        """qpos + s lands exactly on a page edge (full last page)."""
        q, kp, vp, bt = _case(2, 2, 2, 4, 8, ps=8, num_pages=8,
                              table_width=3, seed=5)
        _check(q, kp, vp, bt, [4, 12])           # 4+4=8, 12+4=16

    def test_garbage_beyond_qpos_never_leaks(self):
        """Poisoning every row beyond the visible prefix (recycled pages
        still holding a previous request's KV, unwritten tail rows)
        must not change the output."""
        q, kp, vp, _ = _case(2, 4, 2, 2, 8, ps=4, num_pages=10,
                             table_width=5, seed=6)
        # rows own DISJOINT pages (the allocator's invariant): poisoning
        # one row's hidden tail must not touch the other's visible rows
        perm = np.random.RandomState(7).permutation(10)
        bt = jnp.asarray(perm.reshape(2, 5), jnp.int32)
        qpos = jnp.asarray([5, 9], jnp.int32)
        want = paged_attention_pallas(q, kp, vp, bt, qpos, interpret=True)

        # poison: rewrite rows at logical positions > qpos+s-1 with junk
        kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
        bt_np, ps = np.asarray(bt), 4
        for b in range(2):
            first_hidden = int(qpos[b]) + q.shape[2]
            for t in range(first_hidden, bt_np.shape[1] * ps):
                pg, row = bt_np[b, t // ps], t % ps
                kp2[pg, :, row] = 1e4
                vp2[pg, :, row] = -1e4
        got = paged_attention_pallas(q, jnp.asarray(kp2), jnp.asarray(vp2),
                                     bt, qpos, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **TOL)

    @pytest.mark.slow
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 6),
           st.integers(2, 8), st.integers(0, 2 ** 16))
    def test_shape_sweep(self, b, group, s, ps, seed):
        """Random (batch, group, chunk, page size) sweep; qpos drawn so
        every fill level incl. empty and boundary cases appears."""
        hkv = 2
        rs = np.random.RandomState(seed)
        table_width = int(rs.randint(1, 5))
        num_pages = max(table_width + 1, int(rs.randint(2, 10)))
        q, kp, vp, bt = _case(b, group * hkv, hkv, s, 8, ps=ps,
                              num_pages=num_pages,
                              table_width=table_width, seed=seed)
        hi = max(table_width * ps - s, 0)
        qpos = rs.randint(0, hi + 1, (b,))
        _check(q, kp, vp, bt, qpos)
