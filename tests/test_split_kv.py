"""Split-KV paged attention conformance: kernel ≡ oracle ≡ schedule.

The flash-decoding tentpole has three artifacts that must agree:

* ``paged_attention_pallas(kv_split, pages_per_step)`` — the Pallas
  lowering (interpret mode here): parallel per-partition online-softmax
  partials, multi-page DMA tiles, log-sum-exp combine;
* ``paged_attention_split_ref`` — the explicit recurrence oracle,
  op-for-op the kernel's formulas (shared ``combine_splits``), matched
  to f32 ulp precision (rtol 3e-7 — ~100x tighter than the kernel
  suite's 2e-5; bitwise identity across separately compiled programs is
  not promised, XLA contracts elementwise chains differently);
* ``paged_attention_xla`` — the same schedule through plain XLA scan
  (the CPU-measurable lowering the long-context bench times).

Plus the engine-level contracts: ``kv_split=1, pages_per_step=1`` IS
the pre-split kernel (same code path, byte-for-byte), engine streams
are knob-invariant end to end (chunked prefill, fused decode,
spec-decode verify rounds, dead lanes on the trash page), and the
poisoned-garbage isolation of test_paged_attention.py holds at every
``kv_split``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention import (_paged_attention_unsplit,
                                           auto_pages_per_step,
                                           choose_kv_split, combine_splits,
                                           paged_attention_pallas,
                                           paged_attention_xla)
from repro.kernels.ref import paged_attention_ref, paged_attention_split_ref

TOL = dict(rtol=2e-5, atol=2e-5)
#: the fused≡ref contract for the split kernel: f32 ulp precision
ULP = dict(rtol=3e-7, atol=1e-6)


def _case(b, hq, hkv, s, d, ps, num_pages, table_width, seed=0):
    rs = np.random.RandomState(seed)
    q = jnp.asarray(rs.randn(b, hq, s, d), jnp.float32)
    kp = jnp.asarray(rs.randn(num_pages, hkv, ps, d), jnp.float32)
    vp = jnp.asarray(rs.randn(num_pages, hkv, ps, d), jnp.float32)
    bt = np.stack([rs.permutation(num_pages)[:table_width]
                   for _ in range(b)])
    return q, kp, vp, jnp.asarray(bt, jnp.int32)


# ===========================================================================
class TestSplitEqualsUnsplit:
    def test_knob_1_1_is_the_legacy_kernel_bitwise(self):
        """kv_split=1, pages_per_step=1 must route through the original
        one-page-per-step kernel unchanged — byte-for-byte, not just
        close (the dispatcher's no-regression contract)."""
        q, kp, vp, bt = _case(3, 4, 2, 2, 16, ps=4, num_pages=12,
                              table_width=5)
        qpos = jnp.asarray([0, 6, 17], jnp.int32)
        got = paged_attention_pallas(q, kp, vp, bt, qpos, kv_split=1,
                                     pages_per_step=1, interpret=True)
        legacy = _paged_attention_unsplit(q, kp, vp, bt, qpos,
                                          interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))

    def test_kv_split_1_alone_is_the_legacy_kernel_bitwise(self):
        """An explicit kv_split=1 with the tile left on auto is the
        documented regression baseline ('1 = today's serial page
        chain') — the auto tile must collapse to 1 rather than routing
        through the split kernel's different float association."""
        q, kp, vp, bt = _case(2, 4, 2, 1, 16, ps=4, num_pages=12,
                              table_width=5, seed=13)
        qpos = jnp.asarray([9, 18], jnp.int32)
        got = paged_attention_pallas(q, kp, vp, bt, qpos, kv_split=1,
                                     interpret=True)
        legacy = _paged_attention_unsplit(q, kp, vp, bt, qpos,
                                          interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))

    @pytest.mark.parametrize("split,tile", [(2, 1), (3, 1), (2, 2),
                                            (4, 2), (5, 3)])
    def test_split_matches_unsplit_oracle(self, split, tile):
        """Any knob point must agree with the one-shot softmax oracle
        (semantic equivalence of the whole split+combine pipeline)."""
        q, kp, vp, bt = _case(3, 4, 2, 1, 16, ps=4, num_pages=16,
                              table_width=6, seed=1)
        qpos = jnp.asarray([2, 11, 23], jnp.int32)
        got = paged_attention_pallas(q, kp, vp, bt, qpos, kv_split=split,
                                     pages_per_step=tile, interpret=True)
        want = paged_attention_ref(q, kp, vp, bt, qpos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)

    def test_auto_knobs_match_oracle(self):
        """The cost-model auto point (kv_split=None) is just another
        knob value — same numerics contract."""
        q, kp, vp, bt = _case(2, 4, 2, 1, 16, ps=4, num_pages=20,
                              table_width=12, seed=2)
        qpos = jnp.asarray([40, 17], jnp.int32)
        got = paged_attention_pallas(q, kp, vp, bt, qpos, interpret=True)
        want = paged_attention_ref(q, kp, vp, bt, qpos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# ===========================================================================
class TestKernelVsSplitOracle:
    """Interpret-mode kernel vs the explicit recurrence, at ULP."""

    def _check(self, q, kp, vp, bt, qpos, split, tile):
        qpos = jnp.asarray(qpos, jnp.int32)
        got = paged_attention_pallas(q, kp, vp, bt, qpos, kv_split=split,
                                     pages_per_step=tile, interpret=True)
        want = paged_attention_split_ref(q, kp, vp, bt, qpos,
                                         kv_split=split,
                                         pages_per_step=tile)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **ULP)
        # and the split oracle itself agrees with the one-shot softmax
        base = paged_attention_ref(q, kp, vp, bt, qpos)
        np.testing.assert_allclose(np.asarray(want), np.asarray(base),
                                   **TOL)

    @pytest.mark.parametrize("split", [2, 3, 4])
    def test_ragged_last_partition(self, split):
        """Table width not divisible by split*tile: the last partition
        holds fewer real tiles (pad entries must stay invisible)."""
        q, kp, vp, bt = _case(3, 4, 2, 1, 16, ps=4, num_pages=14,
                              table_width=7, seed=3)
        self._check(q, kp, vp, bt, [27, 9, 0], split, 2)

    @pytest.mark.parametrize("split,tile", [(2, 1), (3, 2), (4, 1)])
    def test_partition_straddles_partial_last_page(self, split, tile):
        """qpos lands mid-page inside a middle partition: everything
        after it (same page, later pages, later partitions) is dead."""
        ps, width = 8, 6
        q, kp, vp, bt = _case(2, 4, 2, 1, 16, ps=ps, num_pages=12,
                              table_width=width, seed=4)
        # row 0: mid-page within partition 1; row 1: exactly a boundary
        self._check(q, kp, vp, bt, [2 * ps + 3, 3 * ps], split, tile)

    @pytest.mark.parametrize("hq,hkv", [(4, 1), (8, 2), (6, 6)])
    def test_gqa_group_folding(self, hq, hkv):
        """Hq folds onto Hkv groups inside each partition; pages are
        fetched per (batch, kv head), never broadcast to Hq."""
        q, kp, vp, bt = _case(2, hq, hkv, 1, 8, ps=4, num_pages=12,
                              table_width=6, seed=5)
        self._check(q, kp, vp, bt, [13, 22], 3, 2)

    @pytest.mark.parametrize("s", [2, 5])
    def test_chunked_prefill_queries(self, s):
        """S > 1 (spec-decode verify / prefill chunks): within-chunk
        causality must hold inside and across partitions."""
        q, kp, vp, bt = _case(3, 4, 2, s, 8, ps=4, num_pages=16,
                              table_width=8, seed=6)
        self._check(q, kp, vp, bt, [0, 9, 21], 2, 2)

    @pytest.mark.slow
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 5),
           st.integers(2, 6), st.integers(1, 6), st.integers(1, 4),
           st.integers(0, 2 ** 16))
    def test_shape_sweep(self, b, group, s, ps, split, tile, seed):
        """Random (batch, group, chunk, page, split, tile) sweep with
        qpos drawn over every fill level."""
        hkv = 2
        rs = np.random.RandomState(seed)
        table_width = int(rs.randint(1, 7))
        num_pages = max(table_width + 1, int(rs.randint(2, 12)))
        q, kp, vp, bt = _case(b, group * hkv, hkv, s, 8, ps=ps,
                              num_pages=num_pages,
                              table_width=table_width, seed=seed)
        hi = max(table_width * ps - s, 0)
        qpos = rs.randint(0, hi + 1, (b,))
        self._check(q, kp, vp, bt, qpos, split, tile)


# ===========================================================================
class TestCombineProperties:
    """Property sweeps of the log-sum-exp combine itself."""

    def _partials(self, rs, split, rows, cols, d):
        """Per-partition online-softmax partials of a random attention
        problem, plus the unsplit answer.  Columns are dealt to
        partitions contiguously, mirroring the kernel's layout; some
        partitions may be fully masked (dead)."""
        logits = rs.randn(rows, split * cols).astype(np.float32)
        v = rs.randn(split * cols, d).astype(np.float32)
        mask = rs.rand(rows, split * cols) < 0.8
        mask[:, 0] = True                      # at least one live column
        lg = np.where(mask, logits, -1e30)
        accs, ms, ls = [], [], []
        for sp in range(split):
            sl = slice(sp * cols, (sp + 1) * cols)
            m = np.max(lg[:, sl], axis=1, keepdims=True)
            m = np.maximum(m, -1e30)
            p = np.exp(lg[:, sl] - m) * mask[:, sl]
            ls.append(np.sum(p, axis=1, keepdims=True))
            accs.append(p @ v[sl])
            ms.append(m)
        # unsplit reference: one softmax over all columns
        m_all = np.max(lg, axis=1, keepdims=True)
        p_all = np.exp(lg - m_all) * mask
        out = (p_all @ v) / np.maximum(p_all.sum(1, keepdims=True), 1e-30)
        return (jnp.asarray(np.stack(accs)), jnp.asarray(np.stack(ms)),
                jnp.asarray(np.stack(ls)), out)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 6), st.integers(1, 8), st.integers(1, 8),
           st.integers(1, 16), st.integers(0, 2 ** 16))
    def test_merge_of_partials_equals_unsplit(self, split, rows, cols, d,
                                              seed):
        rs = np.random.RandomState(seed)
        acc, m, l, want = self._partials(rs, split, rows, cols, d)
        acc_s, _, l_s = combine_splits(acc, m, l)
        got = np.asarray(acc_s / jnp.maximum(l_s, 1e-30))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 6), st.integers(1, 8), st.integers(1, 8),
           st.integers(0, 2 ** 16))
    def test_partition_order_invariance(self, split, rows, cols, seed):
        """The combine must not care which partition was which — the
        grid's parallel lanes complete in arbitrary order."""
        rs = np.random.RandomState(seed)
        acc, m, l, _ = self._partials(rs, split, rows, cols, 8)
        perm = rs.permutation(split)
        a1, _, l1 = combine_splits(acc, m, l)
        a2, _, l2 = combine_splits(acc[perm], m[perm], l[perm])
        np.testing.assert_allclose(
            np.asarray(a1 / jnp.maximum(l1, 1e-30)),
            np.asarray(a2 / jnp.maximum(l2, 1e-30)), rtol=1e-6, atol=1e-6)

    def test_all_dead_partitions_yield_zero(self):
        """Every partition at init state (nothing visible — e.g. a
        dead lane whose table is all trash): the combined output must
        be exactly 0, the unsplit kernel's dead-lane convention."""
        split, rows, d = 3, 4, 8
        acc = jnp.zeros((split, rows, d), jnp.float32)
        m = jnp.full((split, rows, 1), -1e30, jnp.float32)
        l = jnp.zeros((split, rows, 1), jnp.float32)
        acc_s, _, l_s = combine_splits(acc, m, l)
        out = np.asarray(acc_s / jnp.maximum(l_s, 1e-30))
        assert np.all(out == 0.0) and np.all(np.isfinite(out))


# ===========================================================================
class TestDeadLaneAudit:
    """Trash-page / dead-lane isolation at every kv_split.

    Extends test_paged_attention.py's poisoned-garbage test: garbage in
    any row beyond the visible prefix — recycled pages, unwritten tail
    rows, the whole trash page of a dead or mid-block-finished lane —
    must not move ANY partition's partial sum, for every knob point.
    """

    @pytest.mark.parametrize("split", [1, 2, 3, 4])
    @pytest.mark.parametrize("tile", [1, 2])
    def test_poison_beyond_qpos_never_leaks(self, split, tile):
        ps, width, s = 4, 5, 2
        q, kp, vp, _ = _case(2, 4, 2, s, 8, ps=ps, num_pages=10,
                             table_width=width, seed=7)
        perm = np.random.RandomState(8).permutation(10)
        bt = jnp.asarray(perm.reshape(2, width), jnp.int32)
        qpos = jnp.asarray([5, 9], jnp.int32)
        want = paged_attention_pallas(q, kp, vp, bt, qpos, kv_split=split,
                                      pages_per_step=tile, interpret=True)

        kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
        bt_np = np.asarray(bt)
        for b in range(2):
            first_hidden = int(qpos[b]) + s
            for t in range(first_hidden, width * ps):
                pg, row = bt_np[b, t // ps], t % ps
                kp2[pg, :, row] = 1e4
                vp2[pg, :, row] = -1e4
        got = paged_attention_pallas(q, jnp.asarray(kp2),
                                     jnp.asarray(vp2), bt, qpos,
                                     kv_split=split, pages_per_step=tile,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=0)

    @pytest.mark.parametrize("split", [1, 2, 4])
    def test_dead_lane_on_trash_page(self, split):
        """A dead lane (engine convention: block table all trash,
        qpos=0) next to a live lane: poisoning the trash page must not
        move the live lane, and the dead lane's output must stay
        finite (it is masked downstream, but NaN/inf would poison the
        whole fused-loop batch through XLA's NaN propagation)."""
        ps, width, npg = 4, 4, 9
        trash = npg - 1
        q, kp, vp, _ = _case(2, 4, 2, 1, 8, ps=ps, num_pages=npg,
                             table_width=width, seed=9)
        live_pages = np.arange(width)
        bt = jnp.asarray(np.stack([live_pages,
                                   np.full(width, trash)]), jnp.int32)
        qpos = jnp.asarray([11, 0], jnp.int32)
        want = paged_attention_pallas(q, kp, vp, bt, qpos, kv_split=split,
                                      interpret=True)
        kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
        kp2[trash], vp2[trash] = 1e4, -1e4
        got = paged_attention_pallas(q, jnp.asarray(kp2), jnp.asarray(vp2),
                                     bt, qpos, kv_split=split,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                                   rtol=0, atol=0)
        assert np.all(np.isfinite(np.asarray(got[1])))


# ===========================================================================
class TestXlaScheduleLowering:
    """paged_attention_xla (the CPU-measurable schedule) vs the oracle."""

    @pytest.mark.parametrize("split,tile", [(1, 1), (2, 1), (3, 2),
                                            (4, 4)])
    def test_matches_oracle(self, split, tile):
        q, kp, vp, bt = _case(3, 4, 2, 1, 16, ps=4, num_pages=16,
                              table_width=7, seed=10)
        qpos = jnp.asarray([0, 12, 26], jnp.int32)
        got = paged_attention_xla(q, kp, vp, bt, qpos, kv_split=split,
                                  pages_per_step=tile)
        want = paged_attention_ref(q, kp, vp, bt, qpos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)

    @pytest.mark.parametrize("s", [2, 4])
    def test_chunked_queries(self, s):
        q, kp, vp, bt = _case(2, 4, 2, s, 8, ps=4, num_pages=12,
                              table_width=6, seed=11)
        qpos = jnp.asarray([3, 15], jnp.int32)
        got = paged_attention_xla(q, kp, vp, bt, qpos, kv_split=3,
                                  pages_per_step=2)
        want = paged_attention_ref(q, kp, vp, bt, qpos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)

    def test_registered_backend(self):
        from repro.kernels.ops import paged_attention
        q, kp, vp, bt = _case(2, 4, 2, 1, 8, ps=4, num_pages=8,
                              table_width=4, seed=12)
        qpos = jnp.asarray([6, 13], jnp.int32)
        got = paged_attention(q, kp, vp, bt, qpos, backend="xla",
                              kv_split=2, pages_per_step=2)
        want = paged_attention_ref(q, kp, vp, bt, qpos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# ===========================================================================
class TestChooseKvSplit:
    def test_deterministic_and_cached(self):
        a = choose_kv_split(512, 64, 1, batch=4, pages_per_step=16)
        b = choose_kv_split(512, 64, 1, batch=4, pages_per_step=16)
        assert a == b and a >= 1

    def test_single_tile_never_splits(self):
        assert choose_kv_split(64, 4, 2, batch=2, pages_per_step=4) == 1

    def test_long_context_splits(self):
        """At >=64 pages the cost model must actually use the knob —
        otherwise the latency story is vacuous."""
        assert choose_kv_split(512, 64, 1, batch=1,
                               pages_per_step=8) > 1

    def test_auto_pages_per_step_targets_mxu_rows(self):
        assert auto_pages_per_step(8, 64) == 16     # 128-row operand
        assert auto_pages_per_step(256, 64) == 1    # page already > 128
        assert auto_pages_per_step(8, 2) == 2       # capped by the table

    def test_occupancy_boundary_candidate_is_costed(self):
        """lanes exactly at the occupancy target: split=2's predecessor
        saturates, but split=2 itself must still be COSTED before the
        guard fires.  The off-by-one guard broke out first, pinning
        every ``lanes >= target`` geometry to split=1 regardless of
        chain length — 64 serial tiles where 32 would do."""
        # 64 tiles, lanes=512 (the target): split=2 halves the chain
        # (cost 32*4+2=130 < 64*4+1=257) and is the boundary candidate
        assert choose_kv_split(64 * 8, 64, 1, batch=512,
                               pages_per_step=1) == 2

    def test_occupancy_just_below_target_probes_deeper(self):
        # lanes=511: split=2 leaves lanes unsaturated (511 < 512), so
        # split=4 is the boundary candidate and wins on chain length
        assert choose_kv_split(64 * 8, 64, 1, batch=511,
                               pages_per_step=1) == 4

    def test_saturated_lanes_still_split_once(self):
        # lanes far past the target: the guard fires at split=2, but
        # split=2 was already costed and beats the serial chain
        assert choose_kv_split(64 * 8, 64, 1, batch=4096,
                               pages_per_step=1) == 2


# ===========================================================================
def _make_engine_env(seed=0):
    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models.api import get_family
    from repro.nn.context import QuantContext

    cfg = get_config("gemma-2b").smoke()
    fam = get_family(cfg)
    mesh = make_local_mesh()
    params = fam.init(jax.random.PRNGKey(seed), cfg)
    ctx = QuantContext(compute_dtype=jnp.float32)
    return cfg, ctx, fam, mesh, params


def _serve(cfg, ctx, params, mesh, prompts, *, gen_len=8, block=4,
           engine_kw=None):
    from repro.dist.constrain import use_mesh
    from repro.launch.serve import Engine

    with use_mesh(mesh):
        eng = Engine(cfg, ctx, params, mesh, batch=len(prompts),
                     max_len=24, **(engine_kw or {}))
        eng.add_requests(dict(enumerate(prompts)), gen_len=gen_len)
        while eng.live.any():
            eng.step_many(block)
        return [list(eng.outputs[s]) for s in range(len(prompts))], eng


class TestEngineConformance:
    """End-to-end knob invariance through the serving engine."""

    def _prompts(self, cfg, n=3, plen=13):
        from repro.data.pipeline import SyntheticLM
        src = SyntheticLM(cfg.vocab, seed=0)
        return [src.tokens(s, 1, plen + 1)[0, :-1] for s in range(n)]

    def test_kv_split_1_stream_byte_identical(self):
        """kv_split=1 must serve byte-identical streams to the current
        engine (knob plumbed, numerics untouched)."""
        cfg, ctx, fam, mesh, params = _make_engine_env()
        prompts = self._prompts(cfg)
        kw = dict(paged=True, page_size=4)
        base, _ = _serve(cfg, ctx, params, mesh, prompts, engine_kw=kw)
        got, eng = _serve(cfg, ctx, params, mesh, prompts,
                          engine_kw=dict(kw, kv_split=1, pages_per_step=1))
        assert got == base
        st = eng.stats()
        assert st["kv_split"] == 1 and st["pages_per_step"] == 1

    def test_stats_reports_resolved_auto_knobs(self):
        cfg, ctx, fam, mesh, params = _make_engine_env()
        prompts = self._prompts(cfg, n=2)
        _, eng = _serve(cfg, ctx, params, mesh, prompts,
                        engine_kw=dict(paged=True, page_size=4))
        st = eng.stats()
        assert st["kv_split"] >= 1 and st["pages_per_step"] >= 1
        # auto tile targets the MXU operand bound (capped by the table)
        width = eng.block_tables.shape[1]
        assert st["pages_per_step"] == min(128 // 4, width)

    def test_forced_kernel_split_streams_byte_identical(self):
        """The real stack through the real kernel: gather/einsum
        baseline vs the interpret-mode split kernel end to end — same
        prompts, chunked prefill (prompt > chunk), fused decode blocks,
        dead lanes between finish and refill.  Byte-identical greedy
        streams at unsplit AND split knob points."""
        from repro.nn.context import QuantContext
        cfg, ctx, fam, mesh, params = _make_engine_env()
        prompts = self._prompts(cfg)
        kw = dict(paged=True, page_size=4, prefill_chunk=5)
        base, _ = _serve(cfg, ctx, params, mesh, prompts, engine_kw=kw)
        fctx = QuantContext(compute_dtype=jnp.float32,
                            force_paged_kernel=True)
        unsplit, _ = _serve(cfg, fctx, params, mesh, prompts,
                            engine_kw=dict(kw, kv_split=1,
                                           pages_per_step=1))
        split, _ = _serve(cfg, fctx, params, mesh, prompts,
                          engine_kw=dict(kw, kv_split=3,
                                         pages_per_step=2))
        assert unsplit == base
        assert split == base

    @pytest.mark.slow
    def test_spec_decode_through_split_kernel(self):
        """Speculative verify rounds are k+1-token chunked calls of the
        same paged path: greedy streams through the forced split
        kernel must stay byte-identical to the plain engine."""
        from repro.nn.context import QuantContext
        cfg, ctx, fam, mesh, params = _make_engine_env()
        prompts = [np.tile(np.random.RandomState(s).randint(
            0, cfg.vocab, (4,)), 3) for s in (0, 9)]
        kw = dict(paged=True, page_size=4)
        base, _ = _serve(cfg, ctx, params, mesh, prompts, gen_len=10,
                         engine_kw=kw)
        fctx = QuantContext(compute_dtype=jnp.float32,
                            force_paged_kernel=True)
        spec, eng = _serve(cfg, fctx, params, mesh, prompts, gen_len=10,
                           block=2,
                           engine_kw=dict(kw, spec=True, spec_k=3,
                                          kv_split=2, pages_per_step=2))
        assert spec == base
        assert eng.stats()["kv_split"] == 2


# ===========================================================================
class TestLongContextPerf:
    @pytest.mark.slow
    def test_split_kv_speedup_at_64_pages(self):
        """The CI perf smoke: ≥1.5x decode tok/s over the serial page
        chain at ≥64 pages/slot (asserted inside the bench too)."""
        from benchmarks.bench_serving import run_long_context
        rows = run_long_context(iters=30)
        by = {r["name"]: r for r in rows}
        assert by["split_kv"]["speedup_vs_unsplit"] >= 1.5
        assert by["split_kv"]["kv_split"] > 1
