"""Sampling determinism suite for the on-device token draw.

The ``sample_tokens`` op is the one stateful-looking step of the fused
decode loop, so its contract is determinism: given (logits, params, key)
the draw is identical standalone, under ``jax.jit``, and inside
``lax.scan`` — and the fused lowering matches the independent sort-based
oracle in ``repro.kernels.ref`` exactly.  Shape/seed sweeps run through
the deterministic hypothesis stub (tests/_hypothesis_stub.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.ops import sample_tokens
from repro.kernels.ref import sample_tokens_ref
from repro.kernels.sampling import sample_tokens_fused

# the fixed logits fixture the oracle comparison runs on
FIXTURE = np.random.RandomState(1234).randn(6, 96).astype(np.float32)
FIX_TEMP = np.asarray([0.0, 0.5, 0.9, 1.4, 2.0, 0.7], np.float32)
FIX_TOPK = np.asarray([0, 1, 4, 0, 8, 96], np.int32)


def _fix():
    return (jnp.asarray(FIXTURE), jnp.asarray(FIX_TEMP),
            jnp.asarray(FIX_TOPK))


# ===========================================================================
class TestOracleAgreement:
    def test_fused_matches_ref_on_fixture(self):
        logits, temp, topk = _fix()
        for seed in range(16):
            key = jax.random.PRNGKey(seed)
            got = sample_tokens_fused(logits, temp, topk, key)
            want = sample_tokens_ref(logits, temp, topk, key)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_registry_dispatch(self):
        logits, temp, topk = _fix()
        key = jax.random.PRNGKey(0)
        ref = sample_tokens(logits, temp, topk, key, backend="ref")
        fused = sample_tokens(logits, temp, topk, key, backend="pallas")
        default = sample_tokens(logits, temp, topk, key)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(fused))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(default))

    def test_greedy_slots_are_argmax(self):
        logits, temp, topk = _fix()
        out = np.asarray(sample_tokens_fused(logits, temp, topk,
                                             jax.random.PRNGKey(3)))
        am = np.argmax(FIXTURE, axis=-1)
        assert out[0] == am[0]                  # temperature 0.0 slot
        assert out[1] == am[1]                  # top_k 1 slot
        # no key at all: every slot greedy
        np.testing.assert_array_equal(
            np.asarray(sample_tokens_fused(logits, temp, topk, None)), am)


# ===========================================================================
class TestJitBoundaryDeterminism:
    def test_eager_jit_scan_identical(self):
        """The same per-step keys produce the same draws whether the op
        runs eagerly, jitted, or as a lax.scan body — the property the
        fused decode block relies on to match per-token stepping."""
        logits, temp, topk = _fix()
        base = jax.random.PRNGKey(9)
        steps = 5

        eager = jnp.stack([
            sample_tokens_fused(logits, temp, topk,
                                jax.random.fold_in(base, i))
            for i in range(steps)])

        jitted_one = jax.jit(sample_tokens_fused)
        jit_out = jnp.stack([
            jitted_one(logits, temp, topk, jax.random.fold_in(base, i))
            for i in range(steps)])

        @jax.jit
        def scanned():
            def body(_, i):
                key = jax.random.fold_in(base, i)
                return None, sample_tokens_fused(logits, temp, topk, key)
            _, out = jax.lax.scan(body, None, jnp.arange(steps))
            return out

        np.testing.assert_array_equal(np.asarray(eager), np.asarray(jit_out))
        np.testing.assert_array_equal(np.asarray(eager),
                                      np.asarray(scanned()))

    def test_same_seed_reproduces_across_processes_shape(self):
        """Fixed (key, logits) → fixed draw: rerunning the sampler is
        bit-stable (no hidden global state)."""
        logits, temp, topk = _fix()
        key = jax.random.PRNGKey(123)
        a = np.asarray(sample_tokens_fused(logits, temp, topk, key))
        b = np.asarray(sample_tokens_fused(logits, temp, topk, key))
        c = np.asarray(jax.jit(sample_tokens_fused)(logits, temp, topk, key))
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


# ===========================================================================
class TestSamplingSemantics:
    @settings(max_examples=15)
    @given(st.integers(1, 5), st.integers(2, 128), st.integers(0, 2 ** 16),
           st.floats(0.05, 3.0), st.integers(0, 12))
    def test_sweep_fused_matches_ref_and_in_range(self, b, v, seed, temp, k):
        rs = np.random.RandomState(seed)
        logits = jnp.asarray(rs.randn(b, v), jnp.float32)
        temps = jnp.full((b,), temp, jnp.float32)
        topks = jnp.full((b,), k, jnp.int32)
        key = jax.random.PRNGKey(seed)
        got = np.asarray(sample_tokens_fused(logits, temps, topks, key))
        want = np.asarray(sample_tokens_ref(logits, temps, topks, key))
        np.testing.assert_array_equal(got, want)
        assert ((0 <= got) & (got < v)).all()

    @settings(max_examples=10)
    @given(st.integers(1, 8), st.integers(0, 2 ** 16))
    def test_samples_stay_inside_top_k(self, k, seed):
        rs = np.random.RandomState(seed)
        logits = rs.randn(3, 64).astype(np.float32)
        topset = np.argsort(-logits, axis=-1)[:, :k]
        temps = jnp.full((3,), 1.5, jnp.float32)
        topks = jnp.full((3,), k, jnp.int32)
        out = np.asarray(sample_tokens_fused(
            jnp.asarray(logits), temps, topks, jax.random.PRNGKey(seed)))
        for s in range(3):
            assert out[s] in topset[s]

    def test_tied_logits_keep_exactly_k_candidates(self):
        """Ties at the k-th place — routine under int8-dequantized
        heads — must resolve to exactly k candidates identically in
        both lowerings (rank-based candidacy, not a value threshold)."""
        logits = np.full((1, 16), 1.0, np.float32)
        logits[0, :3] = 5.0                     # 13-way tie below the top-3
        temps = jnp.asarray([2.0], jnp.float32)
        topks = jnp.asarray([4], jnp.int32)     # k-th candidate is tied
        allowed = {0, 1, 2, 3}                  # stable argsort: index 3
        for seed in range(24):
            key = jax.random.PRNGKey(seed)
            got = int(sample_tokens_fused(jnp.asarray(logits), temps,
                                          topks, key)[0])
            want = int(sample_tokens_ref(jnp.asarray(logits), temps,
                                         topks, key)[0])
            assert got == want
            assert got in allowed

    def test_top_k_beyond_vocab_and_flat_rows_are_defined(self):
        """k > V behaves as unrestricted; an all-equal row still draws
        a valid id — identically in both lowerings."""
        logits = jnp.asarray(np.zeros((2, 8), np.float32))
        temps = jnp.asarray([1.0, 1.0], jnp.float32)
        topks = jnp.asarray([100, 8], jnp.int32)
        for seed in range(8):
            key = jax.random.PRNGKey(seed)
            got = np.asarray(sample_tokens_fused(logits, temps, topks, key))
            want = np.asarray(sample_tokens_ref(logits, temps, topks, key))
            np.testing.assert_array_equal(got, want)
            assert ((0 <= got) & (got < 8)).all()

    def test_temperature_spreads_and_key_matters(self):
        """Different keys move the sampled slots but never the greedy
        ones (per-slot params mix inside one batch)."""
        logits, temp, topk = _fix()
        draws = np.stack([
            np.asarray(sample_tokens_fused(logits, temp, topk,
                                           jax.random.PRNGKey(s)))
            for s in range(32)])
        assert (draws[:, 0] == draws[0, 0]).all()       # greedy slot fixed
        assert len(set(draws[:, 3].tolist())) > 1       # temp-2.0 slot moves
