"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tables import TableSpec
from repro.kernels import attention, lut_activation, qmatmul
from repro.kernels.ref import (flash_attention_ref, lut_activation_ref,
                               qmatmul_ref)

RNG = np.random.RandomState(0)


class TestLutActivationKernel:
    @pytest.mark.parametrize("shape", [(7,), (3, 5), (2, 130, 3), (1024,),
                                       (256, 128)])
    @pytest.mark.parametrize("indexing", ["trunc", "nearest", "interp"])
    def test_matches_ref(self, shape, indexing):
        spec = TableSpec("sigmoid", 512, -8.0, 8.0, None, indexing)
        x = jnp.asarray(RNG.randn(*shape).astype(np.float32) * 4)
        ref = lut_activation_ref(x, spec)
        pal = lut_activation(x, spec, backend="pallas")
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   atol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        spec = TableSpec("tanh", 256, -4.0, 4.0)
        x = jnp.asarray(RNG.randn(64).astype(np.float32)).astype(dtype)
        ref = lut_activation_ref(x, spec).astype(jnp.float32)
        pal = lut_activation(x, spec, backend="pallas").astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   atol=1e-2)

    def test_quantized_table(self):
        from repro.core.qtypes import AC_FIXED_18_8
        spec = TableSpec("exp", 1024, -16.0, 0.0, AC_FIXED_18_8)
        x = jnp.asarray(-RNG.rand(200).astype(np.float32) * 16)
        np.testing.assert_allclose(
            np.asarray(lut_activation(x, spec, backend="pallas")),
            np.asarray(lut_activation_ref(x, spec)), atol=1e-6)


class TestQMatmulKernel:
    @pytest.mark.parametrize("mkn", [(4, 8, 4), (128, 128, 128),
                                     (130, 300, 70), (256, 512, 384),
                                     (1, 1024, 1)])
    def test_matches_ref(self, mkn):
        m, k, n = mkn
        a = RNG.randint(-127, 128, (m, k)).astype(np.int8)
        b = RNG.randint(-127, 128, (k, n)).astype(np.int8)
        sa = (RNG.rand(m, 1).astype(np.float32) + 0.1) * 0.01
        sb = (RNG.rand(1, n).astype(np.float32) + 0.1) * 0.01
        ref = qmatmul_ref(a, b, sa, sb)
        pal = qmatmul(a, b, sa, sb, backend="pallas")
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)

    def test_scalar_scales(self):
        a = RNG.randint(-127, 128, (32, 64)).astype(np.int8)
        b = RNG.randint(-127, 128, (64, 16)).astype(np.int8)
        ref = qmatmul_ref(a, b, 0.5, 2.0)
        pal = qmatmul(a, b, 0.5, 2.0, backend="pallas")
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref))

    def test_int32_accumulation_exact(self):
        """int8×int8 at K=1024 can reach ±16.6M — must not saturate."""
        a = np.full((8, 1024), 127, np.int8)
        b = np.full((1024, 8), 127, np.int8)
        out = qmatmul(a, b, 1.0, 1.0, backend="pallas")
        assert float(out[0, 0]) == 127.0 * 127.0 * 1024

    @pytest.mark.parametrize("blocks", [(128, 128, 128), (256, 128, 512)])
    def test_block_shapes(self, blocks):
        bm, bn, bk = blocks
        a = RNG.randint(-8, 8, (300, 200)).astype(np.int8)
        b = RNG.randint(-8, 8, (200, 100)).astype(np.int8)
        ref = qmatmul_ref(a, b, 1.0, 1.0)
        pal = qmatmul(a, b, 1.0, 1.0, backend="pallas", bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref))


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("dims", [
        (1, 4, 4, 64, 64, 32),     # MHA
        (2, 8, 2, 100, 100, 64),   # GQA, unaligned seq
        (1, 8, 1, 128, 256, 64),   # MQA, tail queries (Sq < Skv)
        (2, 4, 4, 17, 40, 16),     # tiny ragged
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, dims, causal):
        b, hq, hkv, sq, skv, d = dims
        q = jnp.asarray(RNG.randn(b, hq, sq, d).astype(np.float32))
        k = jnp.asarray(RNG.randn(b, hkv, skv, d).astype(np.float32))
        v = jnp.asarray(RNG.randn(b, hkv, skv, d).astype(np.float32))
        ref = flash_attention_ref(q, k, v, causal=causal)
        pal = attention(q, k, v, causal=causal, backend="pallas")
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   atol=2e-5, rtol=1e-4)

    def test_bf16(self):
        q = jnp.asarray(RNG.randn(1, 2, 64, 32), jnp.bfloat16)
        k = jnp.asarray(RNG.randn(1, 2, 64, 32), jnp.bfloat16)
        v = jnp.asarray(RNG.randn(1, 2, 64, 32), jnp.bfloat16)
        ref = flash_attention_ref(q, k, v, causal=True).astype(jnp.float32)
        pal = attention(q, k, v, causal=True,
                        backend="pallas").astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   atol=3e-2)

    def test_softmax_scale(self):
        q = jnp.asarray(RNG.randn(1, 2, 32, 16).astype(np.float32))
        k = jnp.asarray(RNG.randn(1, 2, 32, 16).astype(np.float32))
        v = jnp.asarray(RNG.randn(1, 2, 32, 16).astype(np.float32))
        ref = flash_attention_ref(q, k, v, causal=True, softmax_scale=0.5)
        pal = attention(q, k, v, causal=True, softmax_scale=0.5,
                        backend="pallas")
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   atol=2e-5, rtol=1e-4)


class TestBackendRegistry:
    def test_fallback_and_contexts(self):
        from repro.core.registry import get_impl, list_ops, use_backend
        assert "lut_activation" in list_ops()
        with use_backend("pallas"):
            f = get_impl("attention")
            assert f is not None
        # unknown backend falls back to ref
        f = get_impl("attention", "verilog", allow_fallback=True)
        assert f is flash_attention_ref
