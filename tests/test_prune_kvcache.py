"""Beyond-deliverable features: pruning (paper §III weights compression),
int8 KV cache, error-feedback compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.prune import (apply_masks, magnitude_mask, make_masks,
                              nm_mask, sparsity)


class TestPruning:
    @settings(max_examples=25, deadline=None)
    @given(st.floats(0.1, 0.9), st.integers(1, 5))
    def test_magnitude_mask_sparsity(self, target, seed):
        w = jnp.asarray(np.random.RandomState(seed).randn(32, 48))
        m = magnitude_mask(w, target)
        kept = float(jnp.mean(m))
        assert abs(kept - (1 - target)) < 0.05
        # the kept entries are exactly the largest-magnitude ones
        thresh = float(jnp.abs(w * m)[m].min())
        assert float(jnp.abs(w * ~m).max()) <= thresh + 1e-7

    @pytest.mark.parametrize("n,m", [(2, 4), (1, 4), (1, 2)])
    def test_nm_mask_structure(self, n, m):
        w = jnp.asarray(np.random.RandomState(0).randn(64, 16))
        mask = nm_mask(w, n, m)
        groups = mask.reshape(64 // m, m, 16)
        counts = jnp.sum(groups, axis=1)
        assert bool(jnp.all(counts == n))
        # kept entries dominate dropped ones within each group
        wg = jnp.abs(w.reshape(64 // m, m, 16))
        kept_min = jnp.min(jnp.where(groups, wg, jnp.inf), axis=1)
        drop_max = jnp.max(jnp.where(~groups, wg, -jnp.inf), axis=1)
        assert bool(jnp.all(kept_min >= drop_max - 1e-7))

    def test_masked_training_keeps_sparsity_and_learns(self):
        """The paper's training-phase sparsity enforcement: mask survives
        optimization and the masked model still fits the task."""
        rng = np.random.RandomState(0)
        W_true = rng.randn(16, 8).astype(np.float32)
        x = jnp.asarray(rng.randn(256, 16), jnp.float32)
        y = x @ W_true
        params = {"w": jnp.asarray(rng.randn(16, 8), jnp.float32)}
        masks = make_masks(params, structured=(2, 4))
        params = apply_masks(params, masks)

        def loss(p):
            return jnp.mean((x @ p["w"] - y) ** 2)

        l0 = float(loss(params))
        for _ in range(100):
            g = jax.grad(loss)(params)
            params = jax.tree_util.tree_map(lambda p, gg: p - 0.02 * gg,
                                            params, g)
            params = apply_masks(params, masks)
        assert sparsity(params) == 0.5
        # a 2:4-masked linear model cannot fit a dense target exactly —
        # assert substantial optimization under the mask, not exact fit
        assert float(loss(params)) < 0.7 * l0


class TestInt8KVCache:
    def test_serving_consistency_and_size(self):
        from repro.configs import get_config
        from repro.models.api import get_family
        from repro.nn.context import QuantContext
        ctx = QuantContext(compute_dtype=jnp.float32)
        cfg = get_config("yi-6b").smoke()
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        B, S, DEC = 2, 8, 3
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + DEC), 0,
                                  cfg.vocab)

        def run(dtype):
            cache = fam.init_cache(cfg, B, S + DEC, dtype)
            lg, cache = fam.prefill(params, toks[:, :S], cache, cfg, ctx)
            pos = jnp.full((B,), S, jnp.int32)
            for t in range(DEC):
                lg, cache = fam.decode_step(params, toks[:, S + t:S + t + 1],
                                            cache, pos + t, cfg, ctx)
            return lg, cache

        lg_f, cache_f = run(jnp.float32)
        lg_q, cache_q = run(jnp.int8)
        rel = float(jnp.abs(lg_f - lg_q).max() / (jnp.abs(lg_f).max()))
        assert rel < 0.05, rel
        assert bool(jnp.all(jnp.argmax(lg_f[:, 0], -1)
                            == jnp.argmax(lg_q[:, 0], -1)))
        # payload really is int8
        assert cache_q["dense"]["k"].dtype == jnp.int8

    def test_quantize_kv_roundtrip_bound(self):
        from repro.nn.attention import _quantize_kv
        u = jnp.asarray(np.random.RandomState(0).randn(2, 4, 8, 32),
                        jnp.float32)
        q, s = _quantize_kv(u)
        back = q.astype(jnp.float32) * s.astype(jnp.float32)
        err = jnp.abs(back - u)
        amax = jnp.abs(u).max(axis=-1, keepdims=True)
        # half-ulp of the int8 grid + the bf16 scale's own rounding error
        bound = amax / 127.0 * 0.5 + amax * 2.0 ** -7
        assert bool(jnp.all(err <= bound + 1e-6))


class TestErrorFeedback:
    def test_residual_cancels_bias(self):
        """Over repeated reductions of the SAME tensor, error feedback
        makes the running mean of reduced values converge to the exact
        reduction (plain quantization keeps a constant bias)."""
        import os
        import subprocess
        import sys
        import textwrap
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = "src"
        code = textwrap.dedent("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.core.qtypes import FixedPointType
            from repro.dist.compression import (quantized_psum,
                                                quantized_psum_ef,
                                                shard_map)
            mesh = jax.make_mesh((4,), ("pod",))
            x = jnp.asarray(np.random.RandomState(0).randn(4, 64),
                            jnp.float32)
            qt = FixedPointType(4, 1)   # brutal 4-bit to expose bias

            def f(x):
                exact = jax.lax.psum(x, "pod")
                r = jnp.zeros_like(x)
                acc_ef = jnp.zeros_like(x)
                acc_q = jnp.zeros_like(x)
                for _ in range(24):
                    out, r = quantized_psum_ef(x, r, "pod", qt)
                    acc_ef += out
                    acc_q += quantized_psum(x, "pod", qt)
                return exact, acc_ef / 24, acc_q / 24

            exact, mean_ef, mean_q = shard_map(
                f, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))(x)
            err_ef = float(jnp.abs(mean_ef - exact).max())
            err_q = float(jnp.abs(mean_q - exact).max())
            print("EF", err_ef, "Q", err_q)
            assert err_ef < 0.5 * err_q, (err_ef, err_q)
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, timeout=300,
                           env=env, cwd=os.path.dirname(
                               os.path.dirname(os.path.abspath(__file__))))
        assert r.returncode == 0, r.stdout + r.stderr
