"""Prefix-cache conformance: shared-prefix streams ≡ cold, byte for byte.

Prefix caching maps already-committed KV pages into a new request's
block table instead of recomputing them, with copy-on-write the moment
a consumer would diverge.  Like paging itself, it must be
*observationally invisible*: for the same submitted requests, a
prefix-cached engine emits exactly the streams a cold engine does —
under speculative decoding, grid-misaligned page sizes, int8 KV pages,
mid-block finishes of one sharer, preemption of sharers, and
snapshot/restore.  The one observable difference is the telemetry
(``prefix_hits`` / ``prefix_tokens_saved`` / ``cow_copies``) and the
prefill work skipped.

Plus the sharing allocator's refcount invariants (hypothesis-stub
interleaving sweeps — no page returns to the free list while anyone
still references it) and the :class:`PrefixIndex` host-side contract
(token re-verification, LRU eviction that never takes a mapped page,
state round-trips).
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.dist.constrain import use_mesh
from repro.launch.paging import PageAllocator
from repro.launch.prefix import PREFIX_OWNER, ROOT, PrefixIndex
from repro.launch.serve import Engine

from test_paged_serving import _prompts, _serve, _setup


def _shared_prompts(cfg, pre_len, tail_lens, seed=0):
    """Prompts sharing one ``pre_len``-token preamble, distinct tails."""
    rs = np.random.RandomState(seed)
    pre = rs.randint(0, cfg.vocab, (pre_len,))
    return [np.concatenate([pre, rs.randint(0, cfg.vocab, (n,))])
            for n in tail_lens]


def _engine(setup, **kw):
    cfg, ctx, params, mesh = setup
    kw.setdefault("batch", 2)
    kw.setdefault("max_len", 32)
    return Engine(cfg, ctx, params, mesh, **kw)


def _drain(eng, block=4):
    while eng.live.any() or eng.waiting:
        eng.step_many(block)
    eng.retire_finished()
    return eng


def _poison_pages(eng, pages):
    """Overwrite physical pages with garbage in every page-pool leaf.

    If any consumer still attends rows in ``pages``, its stream moves;
    the CoW/isolation tests rely on exactly that sensitivity."""
    import jax
    import jax.numpy as jnp
    dst = jnp.asarray(pages, jnp.int32)

    def poison(path, leaf):
        if any(getattr(k, "key", None) == "pages" for k in path):
            fill = jnp.full(leaf[:, dst].shape,
                            127 if leaf.dtype == jnp.int8 else 1e4,
                            leaf.dtype)
            return leaf.at[:, dst].set(fill)
        return leaf

    eng.cache = jax.tree_util.tree_map_with_path(poison, eng.cache)


# ===========================================================================
class TestPrefixConformance:
    """Warm (indexed-prefix) streams byte-identical to cold streams."""

    @pytest.mark.parametrize("quant,spec", [
        ("f32", False),
        ("f32", True),
        pytest.param("int8", False, marks=pytest.mark.slow),
        pytest.param("int8", True, marks=pytest.mark.slow),
    ])
    def test_shared_preamble_matches_cold(self, quant, spec):
        setup = _setup("lm", quant)
        prompts = _shared_prompts(setup[0], 12, (5, 3, 7), seed=1)
        kw = dict(paged=True, page_size=4, max_len=32, spec=spec)
        cold = _serve(setup, prompts, **kw)
        warm = _serve(setup, prompts, prefix_cache=True, **kw)
        assert warm.done == cold.done
        # batch=2: request 3 is admitted after the preamble's pages are
        # committed and published, so at least one admission is a hit
        assert warm.counters["prefix_hits"] >= 1
        assert warm.counters["prefix_tokens_saved"] >= 4
        # pages still referenced after drain are exactly the index's
        assert warm.allocator.used_pages == len(warm.prefix_index)
        assert sorted(warm.allocator.pages_of(PREFIX_OWNER)) \
            == sorted(warm.prefix_index.pages())

    @pytest.mark.parametrize("family", [
        "ssm", pytest.param("hybrid", marks=pytest.mark.slow)])
    def test_flag_is_inert_on_recurrent_families(self, family):
        """ssm/hybrid prefill rebuilds recurrent state from one call's
        tokens — there is no committed-KV page to reuse, so the flag
        must deactivate itself and change nothing."""
        setup = _setup(family, "f32")
        prompts = _shared_prompts(setup[0], 8, (4, 6), seed=2)
        base = _serve(setup, prompts, paged=True, page_size=8)
        on = _serve(setup, prompts, paged=True, page_size=8,
                    prefix_cache=True)
        assert on.done == base.done
        assert on.prefix_cache is False
        assert "prefix_hits" not in on.stats()

    def test_full_prompt_match_copies_boundary_page(self):
        """An exact repeat of an indexed prompt: every page hits, and
        the boundary page — where decode will write — is CoW-duplicated
        so the indexed original stays immutable."""
        setup = _setup("lm", "f32")
        prompt = _prompts(setup[0], (8,), seed=3)[0]
        kw = dict(batch=1, paged=True, page_size=4, max_len=24)
        cold = _serve(setup, [prompt, prompt], **kw)
        warm = _serve(setup, [prompt, prompt], prefix_cache=True, **kw)
        assert warm.done == cold.done
        assert warm.done[0] == warm.done[1]
        assert warm.counters["prefix_hits"] == 1
        assert warm.counters["cow_copies"] == 1
        # full match still prefills the last prompt token (the engine
        # needs its logits): saved = plen - 1
        assert warm.counters["prefix_tokens_saved"] == len(prompt) - 1

    def test_page_size_misaligned_with_prefill_chunk(self):
        """Suffix-only prefill starts mid-chunk-grid when page_size does
        not divide the prefill chunk; streams must not move."""
        setup = _setup("lm", "f32")
        prompts = _shared_prompts(setup[0], 12, (6, 2, 9), seed=4)
        kw = dict(paged=True, page_size=4, prefill_chunk=16, max_len=32)
        cold = _serve(setup, prompts, **kw)
        warm = _serve(setup, prompts, prefix_cache=True, **kw)
        assert warm.done == cold.done
        assert warm.counters["prefix_hits"] >= 1

    @pytest.mark.slow
    def test_int8_kv_pages_share_and_cow_scales_too(self):
        """int8 KV pages carry payload + per-token scale leaves; both
        must share and CoW together or dequantization skews."""
        setup = _setup("lm", "f32")
        prompt = _prompts(setup[0], (8,), seed=5)[0]
        kw = dict(batch=1, kv_bits=8, paged=True, page_size=4, max_len=24)
        cold = _serve(setup, [prompt, prompt], **kw)
        warm = _serve(setup, [prompt, prompt], prefix_cache=True, **kw)
        assert warm.done == cold.done
        assert warm.counters["cow_copies"] == 1


# ===========================================================================
class TestSharerLifecycle:
    """Finishing/preempting ONE consumer of a shared page must never
    disturb the others or the index."""

    def test_midblock_finish_of_one_sharer(self):
        """Two live requests mapping the same prefix pages; the short
        one finishes mid-block and retires.  Its shared holds drop by
        refcount — the pages must NOT return to the free list (the
        index and the long request still map them), and the long
        request's stream must not move."""
        setup = _setup("lm", "f32")
        prompts = _shared_prompts(setup[0], 8, (2, 3), seed=6)
        cfg, ctx, params, mesh = setup
        kw = dict(batch=2, max_len=24, paged=True, page_size=4)
        with use_mesh(mesh):
            cold = _engine(setup, **kw)
            cold.add_requests({0: prompts[0], 1: prompts[1]},
                              gen_len={0: 2, 1: 9})
            _drain(cold)

            eng = _engine(setup, prefix_cache=True, **kw)
            # index the preamble first so both sharers hit it
            eng.submit(prompts[0][:8], gen_len=2)
            eng.try_admit()
            _drain(eng)
            shared_before = eng.allocator.shared_pages()
            eng.add_requests({0: prompts[0], 1: prompts[1]},
                             gen_len={0: 2, 1: 9})
            assert eng.counters["prefix_hits"] == 2
            assert eng.allocator.shared_pages() >= shared_before
            eng.step_many(4)          # slot 0 finishes inside this block
            assert not eng.live[0] and eng.live[1]
            eng.retire_finished()     # drops slot 0's shared holds NOW
            assert eng.outputs[0] is None
            for p in eng.prefix_index.pages():
                assert eng.allocator.refcount(p) >= 1
            _drain(eng)
        assert eng.done[-2:] == cold.done
        # every index page survived the sharer's retirement
        for p in eng.prefix_index.pages():
            assert eng.allocator.refcount(p) >= 1
        assert eng.allocator.used_pages == len(eng.prefix_index)

    def test_preempt_spills_sharer_and_resumes(self):
        """A preempted sharer frees its shared holds (payload copied to
        host) and resumes all-private; streams still byte-identical."""
        setup = _setup("lm", "f32")
        prompts = _shared_prompts(setup[0], 8, (2, 3, 4), seed=7)
        kw = dict(batch=2, max_len=24, gen_len=8, paged=True, page_size=4)
        cold = _serve(setup, prompts, **kw)
        warm = _serve(setup, prompts, prefix_cache=True, preempt=True,
                      preempt_after=1, num_pages=10, **kw)
        assert warm.done == cold.done

    def test_snapshot_restore_round_trips_prefix_state(self):
        """Index entries, per-slot shared holds, and publication
        cursors all survive snapshot/restore mid-flight."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _shared_prompts(cfg, 8, (3, 2, 5), seed=8)
        kw = dict(batch=2, max_len=24, paged=True, page_size=4,
                  prefix_cache=True)
        with use_mesh(mesh):
            ref = _engine(setup, **kw)
            for p in prompts:
                ref.submit(p, gen_len=6)
            ref.try_admit()
            _drain(ref)

            eng = _engine(setup, **kw)
            for p in prompts:
                eng.submit(p, gen_len=6)
            eng.try_admit()
            eng.step_many(2)
            snap = eng.snapshot()
            eng.step_many(4)              # diverge past the snapshot
            eng.restore(snap)
            assert len(eng.prefix_index) == len(snap["prefix_index"]
                                                ["entries"])
            _drain(eng)
        assert eng.done == ref.done
        assert eng.counters["prefix_hits"] == ref.counters["prefix_hits"]


# ===========================================================================
class TestCowIsolation:
    """The divergent writer must be reading its COPY: corrupting the
    shared original after CoW cannot move the writer's stream."""

    def test_poisoned_original_is_never_observed_after_divergence(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompt = _prompts(cfg, (8,), seed=9)[0]
        with use_mesh(mesh):
            solo = _engine(setup, batch=1, max_len=24, paged=True,
                           page_size=4)
            solo.submit(prompt, gen_len=6)
            solo.try_admit()
            _drain(solo)

            eng = _engine(setup, batch=1, max_len=24, paged=True,
                          page_size=4, prefix_cache=True)
            eng.submit(prompt, gen_len=6)
            eng.try_admit()
            _drain(eng)
            # both prompt chunks are now indexed; an exact repeat
            # full-matches and CoW-copies the boundary page
            depth, pages, _ = eng.prefix_index.match(prompt)
            assert depth == 2
            eng.submit(prompt, gen_len=6)
            eng.try_admit()
            assert eng.counters["cow_copies"] == 1
            boundary = pages[-1]
            assert eng.allocator.refcount(boundary) == 1  # index only
            _poison_pages(eng, [boundary])
            _drain(eng)
        # the second stream read its private copy, not the poisoned
        # original — byte-identical to the cold solo run
        assert eng.done == [solo.done[0], solo.done[0]]

    def test_poisoned_free_pages_never_leak_into_warm_stream(self):
        """Sanity for the harness itself: poisoning pages NO table maps
        changes nothing; poisoning a mapped prefix page does.  Together
        these pin that the conformance suite would actually catch a
        sharing bug (the poison is attendable when mapped)."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _shared_prompts(cfg, 8, (3, 3), seed=10)
        with use_mesh(mesh):
            eng = _engine(setup, batch=1, max_len=24, paged=True,
                          page_size=4, prefix_cache=True)
            eng.submit(prompts[0], gen_len=4)
            eng.try_admit()
            _drain(eng)
            free_before = list(eng.allocator._free)
            _poison_pages(eng, free_before)      # garbage in unmapped pages
            eng.submit(prompts[1], gen_len=4)    # hits the clean prefix
            eng.try_admit()
            assert eng.counters["prefix_hits"] == 1
            _drain(eng)

            ref = _engine(setup, batch=1, max_len=24, paged=True,
                          page_size=4)
            for p in prompts:
                ref.submit(p, gen_len=4)
            ref.try_admit()
            _drain(ref)

            bad = _engine(setup, batch=1, max_len=24, paged=True,
                          page_size=4, prefix_cache=True)
            bad.submit(prompts[0], gen_len=4)
            bad.try_admit()
            _drain(bad)
            _poison_pages(bad, bad.prefix_index.pages())
            bad.submit(prompts[1], gen_len=4)
            bad.try_admit()
            _drain(bad)
        assert eng.done == ref.done
        assert bad.done[1] != ref.done[1]        # the poison IS attendable


# ===========================================================================
class TestRefcountProperties:
    """Sharing-allocator invariants under hypothesis-stub sweeps."""

    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 48), st.integers(1, 60), st.integers(0, 2 ** 16))
    def test_no_page_freed_while_referenced(self, num_pages, steps, seed):
        """Random share/free interleavings: a page returns to the free
        list exactly when its LAST reference drops, never before; the
        free list and the referenced set always partition the pool."""
        rs = np.random.RandomState(seed)
        alloc = PageAllocator(num_pages, 4)
        refs = {}                                # page -> model refcount
        for step in range(steps):
            r = rs.rand()
            if refs and r < 0.35:
                page = int(rs.choice(sorted(refs)))
                alloc.free([page])
                refs[page] -= 1
                if refs[page] == 0:
                    del refs[page]
                    assert page in alloc._free
                else:
                    assert page not in alloc._free   # still referenced
            elif refs and r < 0.6:
                page = int(rs.choice(sorted(refs)))
                alloc.share([page])
                refs[page] += 1
            elif alloc.free_pages:
                n = int(rs.randint(1, alloc.free_pages + 1))
                for p in alloc.alloc(n, owner=step):
                    assert p not in refs             # fresh, not recycled-live
                    refs[p] = 1
            for p, n in refs.items():
                assert alloc.refcount(p) == n
            assert alloc.used_pages == len(refs)
            assert alloc.free_pages == num_pages - len(refs)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 32), st.integers(0, 2 ** 16))
    def test_transfer_moves_ownership_not_references(self, num_pages,
                                                     seed):
        rs = np.random.RandomState(seed)
        alloc = PageAllocator(num_pages, 4)
        pages = alloc.alloc(num_pages, owner="slot")
        moved = [p for p in pages if rs.rand() < 0.5]
        alloc.share(moved)
        alloc.transfer(moved, PREFIX_OWNER)
        assert sorted(alloc.pages_of(PREFIX_OWNER)) == sorted(moved)
        assert sorted(alloc.pages_of("slot")) \
            == sorted(set(pages) - set(moved))
        for p in moved:
            assert alloc.refcount(p) == 2
        # spill frees only pages the slot still OWNS — references the
        # slot holds on transferred pages are the caller's to drop
        alloc.spill("slot")
        for p in moved:
            assert alloc.refcount(p) == 2        # untouched by the spill
        assert alloc.used_pages == len(moved)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 32), st.integers(0, 2 ** 16))
    def test_state_round_trip_preserves_refcounts(self, num_pages, seed):
        rs = np.random.RandomState(seed)
        alloc = PageAllocator(num_pages, 8)
        held = alloc.alloc(rs.randint(1, num_pages + 1), owner=0)
        shared = [p for p in held if rs.rand() < 0.5]
        alloc.share(shared)
        state = alloc.state()
        clone = PageAllocator(num_pages, 8)
        clone.load_state(state)
        for p in held:
            assert clone.refcount(p) == alloc.refcount(p)
        assert clone.pages_of(0) == alloc.pages_of(0)
        assert clone._free == alloc._free
        # legacy snapshots (no "ref" key) load as all-refcount-1
        legacy = dict(state)
        del legacy["ref"]
        del legacy["pages"]
        clone2 = PageAllocator(num_pages, 8)
        clone2.load_state(legacy)
        assert all(clone2.refcount(p) == 1 for p in held)

    def test_share_and_free_validate_atomically(self):
        alloc = PageAllocator(4, 8)
        held = alloc.alloc(2, owner="a")
        with pytest.raises(ValueError):
            alloc.share([held[0], 99])           # one bad id: no-op
        assert alloc.refcount(held[0]) == 1
        alloc.share(held)
        alloc.free(held)                         # drops to 1, stays used
        assert alloc.used_pages == 2
        with pytest.raises(ValueError, match="duplicate"):
            alloc.free([held[0], held[0]])
        assert alloc.refcount(held[0]) == 1      # untouched by the raise

    def test_pages_of_tracks_per_owner_without_scanning(self):
        """Per-owner lists: pages_of returns allocation order and stays
        correct through interleaved frees (the O(own pages) fix)."""
        alloc = PageAllocator(12, 4)
        a = alloc.alloc(3, owner="a")
        b = alloc.alloc(2, owner="b")
        a2 = alloc.alloc(2, owner="a")
        assert alloc.pages_of("a") == a + a2
        alloc.free([a[1]])
        assert alloc.pages_of("a") == [a[0]] + a[2:] + a2
        assert alloc.pages_of("b") == b
        assert alloc.pages_of("ghost") == []


# ===========================================================================
class TestPrefixIndexUnit:
    """Host-side index contract: hashing, verification, LRU eviction."""

    def _toks(self, *vals):
        return np.asarray(vals, np.int32)

    def test_match_walks_chain_and_verifies_tokens(self):
        idx = PrefixIndex(2)
        toks = self._toks(1, 2, 3, 4, 5, 6)
        k = idx.keys_for(toks)
        idx.put(k[0], ROOT, toks[:2], page=7, depth=0)
        idx.put(k[1], k[0], toks[2:4], page=8, depth=1)
        depth, pages, key = idx.match(toks)
        assert (depth, pages, key) == (2, [7, 8], k[1])
        # a diverging prompt matches only the agreeing chunks
        depth, pages, _ = idx.match(self._toks(1, 2, 9, 9))
        assert (depth, pages) == (1, [7])
        # shorter than one page: no chunk to match
        assert idx.match(self._toks(1))[0] == 0

    def test_hash_collision_degrades_to_miss(self):
        """Forcing two different chunks onto one key (simulated
        collision): token re-verification turns it into a miss."""
        idx = PrefixIndex(2)
        toks = self._toks(1, 2)
        k = idx.keys_for(toks)[0]
        idx.put(k, ROOT, toks, page=3, depth=0)
        idx._by_key[k].tokens = self._toks(8, 9)     # corrupt the entry
        assert idx.match(toks)[0] == 0               # miss, not wrong page

    def test_double_publish_rejected(self):
        idx = PrefixIndex(2)
        toks = self._toks(4, 4)
        k = idx.keys_for(toks)[0]
        idx.put(k, ROOT, toks, page=0, depth=0)
        with pytest.raises(ValueError, match="already indexed"):
            idx.put(k, ROOT, toks, page=1, depth=0)

    def test_evict_lru_respects_refcounts_and_protect(self):
        """Eviction order is oldest-first; pages any slot still maps
        (refcount > 1) and protected pages are never taken; chains
        dismantle leaf-to-root within an LRU tie."""
        alloc = PageAllocator(8, 2)
        idx = PrefixIndex(2)
        toks = self._toks(1, 2, 3, 4, 5, 6)
        keys = idx.keys_for(toks)
        pages = alloc.alloc(3, owner=PREFIX_OWNER)
        for g, k in enumerate(keys):
            idx.put(k, keys[g - 1] if g else ROOT,
                    toks[2 * g:2 * g + 2], pages[g], depth=g)
        alloc.share([pages[0]])                      # a slot maps chunk 0
        freed = idx.evict(alloc, want=3)
        # chunks 1, 2 freed (deepest-first in the tie); chunk 0 is
        # refcount-2 and must survive
        assert freed == 2
        assert keys[0] in idx and keys[1] not in idx and keys[2] not in idx
        assert alloc.refcount(pages[0]) == 2
        # protect shields an unreferenced page too
        alloc.free([pages[0]])                       # slot drops its hold
        assert idx.evict(alloc, want=1, protect={pages[0]}) == 0
        assert idx.evict(alloc, want=1) == 1
        assert alloc.used_pages == 0

    def test_state_round_trip(self):
        idx = PrefixIndex(4)
        toks = self._toks(*range(8))
        keys = idx.keys_for(toks)
        idx.put(keys[0], ROOT, toks[:4], page=1, depth=0)
        idx.put(keys[1], keys[0], toks[4:], page=2, depth=1)
        idx.match(toks[:4])                          # bump LRU tick
        clone = PrefixIndex(4)
        clone.load_state(idx.state())
        assert clone.match(toks) == idx.match(toks)
        assert len(clone) == 2 and clone._tick == idx._tick
        with pytest.raises(ValueError, match="page_size"):
            PrefixIndex(2).load_state(idx.state())
