"""Per-arch smoke tests (reduced configs, per the brief) + quantization
context variants + serving consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import AC_FIXED_16_6, E4M3, FixedPointType
from repro.models.api import get_family, loss_fn
from repro.nn.context import QuantContext

CTX = QuantContext(compute_dtype=jnp.float32)
ARCHS = [a for a in list_archs() if a != "jet-mlp"]


def make_smoke_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.RandomState(seed)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_input"] = jnp.asarray(
            rng.randn(b, 32, cfg.d_model).astype(np.float32) * 0.1)
    if cfg.family == "vlm":
        batch["img_embed"] = jnp.asarray(
            rng.randn(b, cfg.n_img_tokens, cfg.d_model
                      ).astype(np.float32) * 0.1)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    """One forward/train step per assigned architecture: output shapes
    correct, loss finite, gradients finite and non-trivial."""
    cfg = get_config(arch).smoke()
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    batch = make_smoke_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg, CTX), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert float(metrics["accuracy"]) >= 0.0
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in gleaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in gleaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_logits_shape(arch):
    cfg = get_config(arch).smoke()
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    batch = make_smoke_batch(cfg, b=2, s=16)
    if cfg.family == "lm":
        logits, _, _ = fam.forward(params, batch["tokens"], cfg, CTX)
    elif cfg.family == "encdec":
        logits = fam.forward(params, batch, cfg, CTX)
    elif cfg.family == "vlm":
        logits, _ = fam.forward(params, batch["tokens"],
                                batch["img_embed"], cfg, CTX)
    else:
        logits, _ = fam.forward(params, batch["tokens"], cfg, CTX)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("mode,policy", [
    ("fake", PrecisionPolicy.uniform(AC_FIXED_16_6)),
    ("fake", PrecisionPolicy.uniform(E4M3)),
    ("int8", PrecisionPolicy.uniform(FixedPointType(8, 1))),
])
def test_quantized_context_variants(mode, policy):
    """The paper's quantization modes run end-to-end on a dense LM."""
    cfg = get_config("yi-6b").smoke()
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext(mode=mode, policy=policy,
                       compute_dtype=jnp.float32)
    batch = make_smoke_batch(cfg, s=16)
    loss, _ = loss_fn(params, batch, cfg, ctx)
    assert np.isfinite(float(loss))
    # quantized loss differs from the fp loss but stays in the same range
    loss_fp, _ = loss_fn(params, batch, cfg, CTX)
    assert abs(float(loss) - float(loss_fp)) < 2.0


def test_lut_context_end_to_end():
    """LUT activations + LUT softmax through a full model."""
    cfg = get_config("gemma-2b").smoke()   # GeGLU: gelu tables on the path
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext(use_lut=True, table_n=2048,
                       compute_dtype=jnp.float32)
    batch = make_smoke_batch(cfg, s=16)
    loss_lut, _ = loss_fn(params, batch, cfg, ctx)
    loss_fp, _ = loss_fn(params, batch, cfg, CTX)
    assert np.isfinite(float(loss_lut))
    assert abs(float(loss_lut) - float(loss_fp)) < 0.1


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v2-236b",
                                  "olmoe-1b-7b", "mamba2-370m",
                                  "zamba2-1.2b", "whisper-base",
                                  "llama-3.2-vision-11b"])
def test_serving_chunked_vs_monolithic(arch):
    """prefill(S)+decode(k) must equal prefill(S+k) — cache correctness
    across every cache type (KV, MLA latent, SSM state, cross-KV)."""
    cfg = get_config(arch).smoke()
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    B, S, DEC = 2, 8, 3
    rng = np.random.RandomState(1)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (B, S + DEC)), jnp.int32)
    extras = {}
    if cfg.family == "encdec":
        extras["enc_input"] = jnp.asarray(
            rng.randn(B, 16, cfg.d_model).astype(np.float32) * 0.1)
    if cfg.family == "vlm":
        extras["img_embed"] = jnp.asarray(
            rng.randn(B, cfg.n_img_tokens, cfg.d_model
                      ).astype(np.float32) * 0.1)

    def run_prefill(upto):
        cache = fam.init_cache(cfg, B, S + DEC, jnp.float32)
        if cfg.family in ("encdec", "vlm"):
            return fam.prefill(params, {"tokens": toks[:, :upto], **extras},
                               cache, cfg, CTX)
        return fam.prefill(params, toks[:, :upto], cache, cfg, CTX)

    ref_last, _ = run_prefill(S + DEC)
    lg, cache = run_prefill(S)
    pos = jnp.full((B,), S, jnp.int32)
    for t in range(DEC):
        lg, cache = fam.decode_step(params, toks[:, S + t:S + t + 1],
                                    cache, pos + t, cfg, CTX)
    err = float(jnp.abs(lg[:, 0] - ref_last[:, 0]).max())
    assert err < 1e-3, err


def test_ssd_chunked_equals_stepwise():
    """Mamba-2 SSD chunked scan == naive recurrence (both states)."""
    from repro.nn.ssm import (SSMDims, mamba2_apply, mamba2_decode_step,
                              mamba2_init, mamba2_state_spec)
    d = SSMDims(d_model=32, d_state=8, head_dim=16, expand=2, chunk=4)
    p = mamba2_init(jax.random.PRNGKey(0), d)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    y_chunk, fin = mamba2_apply(p, x, d, CTX)
    state = mamba2_state_spec(d, 2)
    ys = []
    for t in range(16):
        yt, state = mamba2_decode_step(p, x[:, t:t + 1], state, d, CTX)
        ys.append(yt)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(fin["ssm"]),
                               np.asarray(state["ssm"]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(fin["conv"]),
                               np.asarray(state["conv"]), atol=1e-6)


def test_moe_balance_and_capacity():
    """MoE routes every token somewhere (dropless) and respects capacity."""
    from repro.nn.moe import MoEDims, moe_apply, moe_init
    d = MoEDims(d_model=16, d_ff=32, n_experts=4, top_k=2)
    p = moe_init(jax.random.PRNGKey(0), d)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_apply(p, x, d, CTX, dropless=True)
    assert y.shape == x.shape
    assert float(aux) >= 1.0  # Switch aux loss lower bound is 1 at balance
    # output actually depends on routing (not all-zero)
    assert float(jnp.abs(y).max()) > 0


def test_n_params_analytic_vs_actual():
    """ModelConfig.n_params must match the real parameter count (it feeds
    the roofline's MODEL_FLOPS)."""
    for arch in ["yi-6b", "gemma-2b", "olmoe-1b-7b", "mamba2-370m"]:
        cfg = get_config(arch).smoke()
        fam = get_family(cfg)
        shapes = jax.eval_shape(
            lambda: fam.init(jax.random.PRNGKey(0), cfg))
        actual = sum(np.prod(l.shape) for l in
                     jax.tree_util.tree_leaves(shapes))
        predicted = cfg.n_params()
        assert abs(actual - predicted) / actual < 0.02, \
            (arch, actual, predicted)
