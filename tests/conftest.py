import os
import sys

# Tests must see exactly ONE device (the dry-run sets 512 in its own
# process); keep any user XLA_FLAGS but never the host-device override.
flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" in flags:
    parts = [f for f in flags.split() if "host_platform_device_count" not in f]
    os.environ["XLA_FLAGS"] = " ".join(parts)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# `hypothesis` is not available in the CI container; install the local
# deterministic stub unless the real package exists.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
