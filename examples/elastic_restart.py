"""Fault tolerance + elasticity demo: train with injected failures,
recover from checkpoints, then restart the SAME checkpoint on a DIFFERENT
mesh shape (the elastic-rescale path a 1000-node deployment needs when a
pod is lost).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import make_batch
from repro.dist.constrain import use_mesh
from repro.dist.sharding import batch_specs, named, param_specs
from repro.ft import FaultInjector, ResilientLoop, StragglerMonitor
from repro.nn.context import QuantContext
from repro.train.step import build_train_step, init_state


def run_on_mesh(mesh, ckpt_dir, steps, fail_at=()):
    cfg = get_config("yi-6b").smoke()
    ctx = QuantContext(compute_dtype=jnp.float32)
    step_fn = build_train_step(cfg, ctx, lr_fn=lambda s: 1e-3,
                               microbatches=1)
    with use_mesh(mesh):
        state = init_state(jax.random.PRNGKey(0), cfg)
        st_sh = named(param_specs(state, mesh), mesh)
        state = jax.device_put(state, st_sh)
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

        def batch_fn(step):
            b = make_batch(cfg, step, 8, 32)
            return jax.device_put(b, named(batch_specs(b, mesh), mesh))

        b_sh = named(batch_specs(batch_fn(0), mesh), mesh)
        jstep = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                        out_shardings=(st_sh, rep), donate_argnums=(0,))

        mgr = CheckpointManager(ckpt_dir, keep=3)
        restored, ckstep = mgr.restore_latest(
            jax.tree_util.tree_map(np.asarray, state), shardings=st_sh)
        start = 0
        if restored is not None:
            state, start = restored, ckstep
            print(f"  resumed from step {start} onto mesh "
                  f"{dict(mesh.shape)}")

        mon = StragglerMonitor()
        loop = ResilientLoop(jstep, batch_fn, mgr, checkpoint_every=5,
                             fault_injector=FaultInjector(fail_at),
                             straggler=mon)
        out = loop.run(state, start_step=start, num_steps=steps,
                       shardings=st_sh)
        print(f"  reached step {out['step']}, "
              f"loss {float(out['metrics']['loss']):.4f}, "
              f"restores={out['restores']}")
        return out


def main():
    n = len(jax.devices())
    ckpt = tempfile.mkdtemp(prefix="elastic_")
    print(f"devices: {n}; checkpoints: {ckpt}")

    print("\nPhase 1: (n//2, 2) mesh with injected faults at steps 7, 12")
    mesh1 = jax.make_mesh((max(n // 2, 1), min(2, n)), ("data", "model"))
    run_on_mesh(mesh1, ckpt, steps=15, fail_at=(7, 12))

    print("\nPhase 2: elastic restart on a (n, 1) mesh — same checkpoint")
    mesh2 = jax.make_mesh((n, 1), ("data", "model"))
    run_on_mesh(mesh2, ckpt, steps=10)

    print("\nelastic restart OK")


if __name__ == "__main__":
    main()
