"""END-TO-END DRIVER (deliverable b): serve a small model with batched
requests — the paper's deployment scenario (a quantized inference
accelerator) at framework level.

Continuous batching over batched chunked prefill and the device-resident
fused decode loop (``--decode-block`` steps per jit call; host syncs once
per block); quantized weights + activations through the ``QuantContext``;
LUT activations on the hot path.  Compares fp32 vs quantized serving:
throughput and greedy agreement — and the per-token decode baseline
(``--decode-block 1``) vs the fused loop.

Run:  PYTHONPATH=src python examples/serve_quantized.py
      (add --arch yi-6b --requests 32 ... to scale up; --temperature /
       --top-k switch slots from greedy to on-device sampling)
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not argv:
        print("== fp32 serving, per-token decode (baseline) ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "4", "--prompt-len", "16", "--gen-len", "16",
              "--decode-block", "1"])
        print("\n== fp32 serving, fused decode loop (8 tokens/dispatch) ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "4", "--prompt-len", "16", "--gen-len", "16",
              "--decode-block", "8"])
        print("\n== quantized (ac_fixed fake-quant) + LUT + fused decode ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "4", "--prompt-len", "16", "--gen-len", "16",
              "--quant", "fake", "--lut", "--decode-block", "8"])
    else:
        main(argv)
