"""END-TO-END DRIVER (deliverable b): serve a small model with batched
requests — the paper's deployment scenario (a quantized inference
accelerator) at framework level.

Continuous batching over prefill/decode steps; quantized weights +
activations through the ``QuantContext``; LUT activations on the hot path.
Compares fp32 vs quantized serving: throughput and greedy agreement.

Run:  PYTHONPATH=src python examples/serve_quantized.py
      (add --arch yi-6b --requests 32 ... to scale up)
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not argv:
        print("== fp32 serving ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "4", "--prompt-len", "16", "--gen-len", "16"])
        print("\n== quantized (ac_fixed fake-quant) + LUT serving ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "4", "--prompt-len", "16", "--gen-len", "16",
              "--quant", "fake", "--lut"])
    else:
        main(argv)
