"""END-TO-END DRIVER (deliverable b): serve a small model with batched
requests — the paper's deployment scenario (a quantized inference
accelerator) at framework level.

Continuous batching over batched chunked prefill and the device-resident
fused decode loop (``--decode-block`` steps per jit call; host syncs once
per block); quantized weights + activations through the ``QuantContext``;
LUT activations on the hot path.  Compares fp32 vs quantized serving:
throughput and greedy agreement — and the per-token decode baseline
(``--decode-block 1``) vs the fused loop.

Paged KV cache (``--paged``): K/V rows live in a shared pool of
``--num-pages`` pages of ``--page-size`` tokens instead of a dense
``max_len`` allocation per slot, and each request holds exactly the
pages its token budget needs.  Requests queue via ``submit()`` and are
admitted the moment freed pages cover their prompt — so with mixed
prompt lengths the same KV HBM serves ~2x the concurrent requests
(byte-identical outputs; see tests/test_paged_serving.py).  Dense mode
still wins for tiny batches (1-2 requests): it has no block-table
indirection or page-gather overhead and a lone request cannot benefit
from pooling — page in when traffic is mixed and concurrent, not for a
single stream.

Split-KV paged attention (``--kv-split`` / ``--pages-per-step``,
default ``auto``): the kernel-side reuse-factor knob for long-context
decode — each slot's page chain is cut into ``kv_split`` parallel
flash-decoding partitions (merged by a log-sum-exp combine) and each
grid step fetches a ``pages_per_step``-page tile, double-buffered.
``auto`` resolves both from a cached cost model per cache geometry;
the exit stats table prints the resolved pair.  ``--kv-split 1
--pages-per-step 1`` reproduces the pre-split kernel byte-for-byte.

Speculative decoding (``--spec``): a drafter proposes ``--spec-k``
tokens per round (prompt-lookup by default; ``--spec-draft <arch>``
uses a second model) and the target verifies them all with ONE forward
pass.  Greedy streams stay byte-identical to the non-speculative
engine (tests/test_speculative.py); the exit stats table reports how
many drafts each verify round committed.

Each run prints an ``Engine.stats()`` summary table at exit: requests,
peak concurrency, decode tok/s, mean TTFT, and (speculative) drafts
accepted per verify round.

Run:  PYTHONPATH=src python examples/serve_quantized.py
      (add --arch yi-6b --requests 32 ... to scale up; --temperature /
       --top-k switch slots from greedy to on-device sampling;
       --paged --page-size 16 --num-pages 64 pools the KV cache;
       --spec --spec-k 6 turns on speculative decoding)
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not argv:
        print("== fp32 serving, per-token decode (baseline) ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "4", "--prompt-len", "16", "--gen-len", "16",
              "--decode-block", "1"])
        print("\n== fp32 serving, fused decode loop (8 tokens/dispatch) ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "4", "--prompt-len", "16", "--gen-len", "16",
              "--decode-block", "8"])
        print("\n== quantized (ac_fixed fake-quant) + LUT + fused decode ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "4", "--prompt-len", "16", "--gen-len", "16",
              "--quant", "fake", "--lut", "--decode-block", "8"])
        print("\n== paged KV cache: same KV rows as batch-4 dense, "
              "8 lanes ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "8", "--prompt-len", "16", "--gen-len", "16",
              "--decode-block", "8", "--paged", "--page-size", "8",
              "--num-pages", "17"])
        print("\n== paged + split-KV: auto-resolved reuse-factor knob "
              "(see 'kv split / pages per step' in the stats table) ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "8", "--prompt-len", "16", "--gen-len", "16",
              "--decode-block", "8", "--paged", "--page-size", "4",
              "--num-pages", "34", "--kv-split", "auto",
              "--pages-per-step", "auto"])
        print("\n== speculative decoding: prompt-lookup drafts, "
              "one verify pass per round ==")
        main(["--arch", "gemma-2b", "--smoke", "--requests", "8",
              "--batch", "4", "--prompt-len", "16", "--gen-len", "32",
              "--decode-block", "4", "--spec", "--spec-k", "4"])
    else:
        main(argv)
