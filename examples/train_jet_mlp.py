"""The paper's canonical use case end-to-end: train the hls4ml jet-tagging
MLP (16→64→32→32→5) in fp32, post-training-quantize it across the paper's
§IV-B design space (fixed point AND custom minifloats), and deploy with
the table-based softmax.

Run:  PYTHONPATH=src python examples/train_jet_mlp.py [--steps 400]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import FixedPointType, MiniFloatType
from repro.models import mlp
from repro.nn.context import QuantContext


def jet_data(n, seed=0):
    """Synthetic jet-tagging-like task: 16 features → 5 classes.  Class
    centers are FIXED (task identity); ``seed`` draws fresh noise/labels
    (train/test splits share the task)."""
    rng_task = np.random.RandomState(0)
    centers = rng_task.randn(5, 16) * 2.0
    rng = np.random.RandomState(seed + 1)
    y = rng.randint(0, 5, n)
    x = centers[y] + rng.randn(n, 16) * 1.0
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()

    x, y = jet_data(4096)
    xt, yt = jet_data(4096, seed=9)
    params = mlp.init(jax.random.PRNGKey(0))
    ctx32 = QuantContext(compute_dtype=jnp.float32)

    @jax.jit
    def step(p):
        (_, m), g = jax.value_and_grad(mlp.loss, has_aux=True)(
            p, {"x": x, "y": y}, ctx32)
        return jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g), m

    for i in range(args.steps):
        params, m = step(params)
        if i % 100 == 0:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"acc {float(m['accuracy']):.3f}")

    def test_acc(ctx):
        p = mlp.forward(params, xt, ctx)
        return float(jnp.mean(jnp.argmax(p, -1) == yt))

    acc_fp = test_acc(ctx32)
    print(f"\nfp32 test accuracy: {acc_fp:.4f}\n")
    print(f"{'format':<16s} {'bits':>4s} {'accuracy':>9s} {'delta':>8s}")
    for qt in [FixedPointType(16, 6), FixedPointType(10, 4),
               FixedPointType(8, 3), FixedPointType(6, 2),
               MiniFloatType(5, 2), MiniFloatType(4, 3, ieee_inf=False),
               MiniFloatType(3, 4)]:
        ctx = QuantContext(mode="fake",
                           policy=PrecisionPolicy.uniform(qt, qt),
                           compute_dtype=jnp.float32)
        acc = test_acc(ctx)
        bits = qt.width
        print(f"{qt.short_name():<16s} {bits:>4d} {acc:>9.4f} "
              f"{acc - acc_fp:>+8.4f}")

    # deployment: LUT softmax (paper §III tables, 1024×18-bit override)
    ctx_lut = QuantContext(use_lut=True, compute_dtype=jnp.float32)
    probs_lut = mlp.predict(params, xt[:8], ctx_lut)
    probs_fp = mlp.predict(params, xt[:8], ctx32)
    print(f"\nLUT-softmax max |Δp| vs exact: "
          f"{float(jnp.abs(probs_lut - probs_fp).max()):.2e}")


if __name__ == "__main__":
    main()
