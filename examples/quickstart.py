"""Quickstart: the paper's de-specialized component library in 5 minutes.

Covers: parametric fixed-point/minifloat types, trace-time constant tables
(the constexpr analogue, incl. hls4ml's softmax-table override), per-layer
heterogeneous precision, backend-pluggable kernels, and a quantized
forward pass through an assigned architecture.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AC_FIXED_16_6, AC_FIXED_18_8, E4M3, FixedPointType,
                        LayerPrecision, PrecisionPolicy, TableSpec,
                        fake_quant, softmax_table_policy, table_softmax)
from repro.kernels import attention, lut_activation, qmatmul

print("=" * 70)
print("1. Parametric numeric formats (the ac_types analogue)")
print("=" * 70)
x = jnp.asarray([0.123456, -3.9, 31.99, 100.0])
print(f"ac_fixed<16,6>  {AC_FIXED_16_6.short_name()}:",
      fake_quant(x, AC_FIXED_16_6))
print("E4M3 minifloat (OCP, max 448):", fake_quant(x, E4M3))
custom = FixedPointType(width=10, int_bits=3, rounding="trn",
                        overflow="wrap")
print("custom ac_fixed<10,3,TRN,WRAP>:", fake_quant(x, custom))

print()
print("=" * 70)
print("2. Trace-time constant tables ('constexpr' for XLA)")
print("=" * 70)
spec = TableSpec("gelu_gate", n=1024, lo=-8.0, hi=8.0,
                 qtype=AC_FIXED_18_8, indexing="interp")
g = jnp.linspace(-4, 4, 9)
print("LUT gelu (gated, 18-bit table):",
      np.round(np.asarray(g * lut_activation(g, spec)), 4))
print("exact gelu:                    ",
      np.round(np.asarray(jax.nn.gelu(g)), 4))

# the paper's §III finding: softmax overrides your type with 1024×18-bit
pol = softmax_table_policy(FixedPointType(8, 3))
print(f"softmax table policy (override): n={pol.n}, "
      f"qtype={pol.qtype.short_name()}")
z = jnp.asarray([[1.0, 2.0, 3.0]])
print("table softmax:", table_softmax(z, policy=pol),
      " exact:", jax.nn.softmax(z))

print()
print("=" * 70)
print("3. Backend-pluggable kernels (ref ≡ pallas, CPU interpret mode)")
print("=" * 70)
a = jnp.asarray(np.random.RandomState(0).randint(-127, 128, (64, 128)),
                jnp.int8)
b = jnp.asarray(np.random.RandomState(1).randint(-127, 128, (128, 32)),
                jnp.int8)
o_ref = qmatmul(a, b, 0.01, 0.02, backend="ref")
o_pal = qmatmul(a, b, 0.01, 0.02, backend="pallas")
print("int8 qmatmul ref-vs-pallas max diff:",
      float(jnp.abs(o_ref - o_pal).max()))

print()
print("=" * 70)
print("4. Per-layer heterogeneous precision on a real architecture")
print("=" * 70)
from repro.configs import get_config
from repro.models.api import get_family, loss_fn
from repro.nn.context import QuantContext

cfg = get_config("deepseek-v2-236b").smoke()   # MLA + MoE, reduced dims
fam = get_family(cfg)
params = fam.init(jax.random.PRNGKey(0), cfg)
policy = (PrecisionPolicy.uniform(AC_FIXED_16_6)
          .with_override("*router*", LayerPrecision())       # router fp32
          .with_override("*wkv_a*", LayerPrecision()))       # latent fp32
ctx = QuantContext(mode="fake", policy=policy, use_lut=True,
                   compute_dtype=jnp.float32)
batch = {"tokens": jnp.ones((2, 16), jnp.int32),
         "labels": jnp.ones((2, 16), jnp.int32)}
loss_q, _ = loss_fn(params, batch, cfg, ctx)
loss_f, _ = loss_fn(params, batch, cfg,
                    QuantContext(compute_dtype=jnp.float32))
print(f"deepseek-v2 (smoke) loss fp32={float(loss_f):.4f} "
      f"quantized+LUT={float(loss_q):.4f}")
print("done.")
